//! L3 coordination: the compression pipeline.
//!
//! The paper's workload shape is a *data pipeline*: a stream of per-layer
//! compression jobs (Table 4.1 compresses 3 layers for VGG, 38 for ViT;
//! a sweep multiplies that by the α×q×trial grid). The coordinator owns:
//!
//! * [`pool`]  — a from-scratch worker thread pool (no tokio in the
//!   offline crate universe).
//! * [`queue`] — a bounded MPMC job queue providing backpressure: the
//!   planner blocks when workers fall behind, keeping peak memory
//!   proportional to queue depth, not model size.
//! * [`pipeline`] — the end-to-end flow: checkpoint → plan → compress
//!   (per-layer jobs on the pool) → validate → emit compressed checkpoint
//!   + metrics. The pipeline owns one persistent pool, resolves its
//!   factorization strategy through `compress::factorizer`'s registry,
//!   and materializes weights inside worker tasks so peak memory tracks
//!   in-flight work, not model size.
//! * [`metrics`] — counters/timers reported in pipeline summaries.

pub mod metrics;
pub mod pipeline;
pub mod pool;
pub mod queue;

pub use metrics::PipelineMetrics;
pub use pipeline::{LayerOutcome, Pipeline, PipelineConfig, PipelineReport};
pub use pool::{JobHandle, WorkerPool};
pub use queue::BoundedQueue;
