//! # rsi-compress
//!
//! Low-rank compression of pretrained models via **randomized subspace
//! iteration (RSI)** — a production-shaped reproduction of
//! Pourkamali-Anaraki, *"Low-Rank Compression of Pretrained Models via
//! Randomized Subspace Iteration"* (CS.LG 2026).
//!
//! The crate is the L3 (coordination) layer of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the VMEM-tiled
//!   GEMM hot spot of the RSI power iteration, plus a fused softmax head.
//! * **L2** — JAX graphs (`python/compile/model.py`): the RSI pipeline and
//!   the model forward passes, lowered once to HLO text by
//!   `python/compile/aot.py` (`make artifacts`).
//! * **L3** — this crate: checkpoint I/O, the compression planner, a
//!   work-queue pipeline over layers, PJRT execution of the AOT artifacts,
//!   the evaluation engine, a batched serving engine for compressed
//!   checkpoints (`serve`, behind `rsic serve`), and the paper's benchmark
//!   harness.
//!
//! Python never runs on the request path; after `make artifacts` the `rsic`
//! binary is self-contained.
//!
//! ## Quick start
//!
//! ```no_run
//! use rsi_compress::compress::{CompressionPlan, Method, RsiOptions};
//! use rsi_compress::io::tenz::TensorFile;
//! use rsi_compress::coordinator::pipeline::{Pipeline, PipelineConfig};
//!
//! let ckpt = TensorFile::read("artifacts/data/synthvgg.tenz").unwrap();
//! let plan = CompressionPlan::uniform_alpha(0.4, Method::Rsi(RsiOptions { q: 4, ..Default::default() }));
//! let pipe = Pipeline::new(PipelineConfig::default()).unwrap();
//! let report = pipe.compress_checkpoint(&ckpt, &plan).unwrap();
//! println!("{}", report.summary());
//! ```
//!
//! For checkpoints that should never be fully resident, open lazily and
//! stream the output (planning reads headers only; each worker
//! materializes one weight at a time):
//!
//! ```no_run
//! use std::sync::Arc;
//! use rsi_compress::compress::{CompressionPlan, Method, RsiOptions};
//! use rsi_compress::io::checkpoint::CheckpointReader;
//! use rsi_compress::coordinator::pipeline::{Pipeline, PipelineConfig};
//!
//! let src = Arc::new(CheckpointReader::open("artifacts/data/synthvgg.tenz").unwrap());
//! let plan = CompressionPlan::uniform_alpha(0.4, Method::Rsi(RsiOptions { q: 4, ..Default::default() }));
//! let pipe = Pipeline::new(PipelineConfig::default()).unwrap();
//! let report = pipe.compress_to_path(src, &plan, "compressed.tenz").unwrap();
//! println!("{}", report.summary());
//! ```

pub mod bench;
pub mod cli;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod io;
pub mod linalg;
pub mod model;
pub mod obs;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod testutil;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Version string baked into reports and the CLI banner.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Default location of AOT artifacts relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts directory: `$RSIC_ARTIFACTS` overrides the
/// default `artifacts/` (relative to the current directory).
pub fn artifacts_dir() -> std::path::PathBuf {
    match std::env::var("RSIC_ARTIFACTS") {
        Ok(v) if !v.is_empty() => std::path::PathBuf::from(v),
        _ => std::path::PathBuf::from(ARTIFACTS_DIR),
    }
}
