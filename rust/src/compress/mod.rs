//! The paper's core contribution: low-rank compression of linear layers
//! via randomized subspace iteration (Algorithm 3.1).
//!
//! * [`rsi`] — the algorithm itself, generic over a [`backend::GemmEngine`]
//!   so the O(C·D·k) GEMM hot spot can run natively or through the AOT
//!   Pallas/XLA artifacts.
//! * [`plan`] — the compression planner: the α → per-layer rank rule,
//!   parameter accounting, and layer selection.
//! * [`factor`] — the rank-k factorization type (A·B with diagnostics).
//! * [`factorizer`] — the pluggable strategy layer: the [`Factorizer`]
//!   trait, the shipped implementations (exact SVD, RSI, fused-XLA with
//!   fallback), and the registry that resolves `(Method, BackendKind)`.
//! * [`backend`] — GEMM engine trait + the native engine; the PJRT engine
//!   lives in `runtime::xla_engine`.
//! * [`error`] — approximation-quality metrics (normalized spectral error).

pub mod adaptive;
pub mod backend;
pub mod error;
pub mod factor;
pub mod factorizer;
pub mod plan;
pub mod rsi;

pub use adaptive::{allocate_ranks, LayerSpectrum};
pub use backend::{BackendKind, GemmEngine, NativeEngine};
pub use factor::Factorization;
pub use factorizer::{
    BackendResources, ExactSvdFactorizer, Factorizer, FactorizerRegistry, FusedRsiExec,
    FusedXlaFactorizer, RsiFactorizer, WithFallback,
};
pub use plan::{CompressionPlan, LayerPlan, Method};
pub use rsi::{rsi_factorize, OrthoStrategy, RsiOptions};
