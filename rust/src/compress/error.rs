//! Approximation-quality metrics tying the code back to the paper's
//! reported numbers.

use super::factor::Factorization;
use crate::linalg::svd::Svd;
use crate::tensor::Mat;

/// Everything the single-layer figures report for one (k, q, trial) cell.
#[derive(Debug, Clone)]
pub struct QualityReport {
    /// ‖W − A·B‖₂ (power-iteration estimate).
    pub spectral_error: f64,
    /// ‖W − A·B‖₂ / s_{k+1} — Figs 1.1b, 4.1a, 4.2a. 1.0 is optimal.
    pub normalized_error: f64,
    /// The optimal error s_{k+1} itself.
    pub optimal_error: f64,
    /// Relative Frobenius reconstruction error (secondary diagnostic).
    pub rel_fro_error: f64,
}

/// Evaluate a factorization against the exact SVD of the same matrix.
pub fn quality(w: &Mat<f32>, f: &Factorization, exact: &Svd) -> QualityReport {
    let k = f.rank();
    let spectral_error = f.spectral_error(w);
    let optimal_error = exact.s.get(k).copied().unwrap_or(0.0);
    let normalized_error = crate::linalg::norms::normalized_error(spectral_error, optimal_error);
    let resid = w.sub(&f.reconstruct());
    let wf = w.fro_norm().max(f64::MIN_POSITIVE);
    QualityReport {
        spectral_error,
        normalized_error,
        optimal_error,
        rel_fro_error: resid.fro_norm() / wf,
    }
}

/// Evaluate when the exact spectrum is known analytically (synthetic
/// matrices) without computing an SVD.
pub fn quality_vs_spectrum(w: &Mat<f32>, f: &Factorization, spectrum: &[f64]) -> QualityReport {
    let k = f.rank();
    let spectral_error = f.spectral_error(w);
    let optimal_error = spectrum.get(k).copied().unwrap_or(0.0);
    let normalized_error = crate::linalg::norms::normalized_error(spectral_error, optimal_error);
    let resid = w.sub(&f.reconstruct());
    let wf = w.fro_norm().max(f64::MIN_POSITIVE);
    QualityReport {
        spectral_error,
        normalized_error,
        optimal_error,
        rel_fro_error: resid.fro_norm() / wf,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::backend::NativeEngine;
    use crate::compress::rsi::{rsi_factorize, RsiOptions};
    use crate::linalg::svd::svd_via_gram;
    use crate::rng::GaussianSource;
    use crate::tensor::init::{matrix_with_spectrum, SpectrumShape};

    #[test]
    fn exact_svd_truncation_scores_one() {
        let mut g = GaussianSource::new(1);
        let spec = SpectrumShape::pretrained_like().values(24);
        let w = matrix_with_spectrum(24, 60, &spec, &mut g);
        let svd = svd_via_gram(&w);
        let k = 6;
        let (a, b) = svd.factors(k);
        let f = Factorization { a, b, s: svd.s[..k].to_vec() };
        let q = quality(&w, &f, &svd);
        assert!((q.normalized_error - 1.0).abs() < 0.02, "got {}", q.normalized_error);
        assert!(q.rel_fro_error < 1.0);
    }

    #[test]
    fn rsvd_scores_above_one_on_slow_decay() {
        let mut g = GaussianSource::new(2);
        let spec = SpectrumShape::pretrained_like().values(48);
        let w = matrix_with_spectrum(48, 120, &spec, &mut g);
        let f = rsi_factorize(&w, 8, &RsiOptions::rsvd(3), &NativeEngine);
        let q = quality_vs_spectrum(&w, &f, &spec);
        assert!(q.normalized_error > 1.05, "RSVD unexpectedly near-optimal: {}", q.normalized_error);
        assert!((q.optimal_error - spec[8]).abs() < 1e-12);
    }
}
