//! Adaptive layer-wise rank selection — the paper's first future-work item
//! (§5: "developing adaptive strategies for selecting layer-wise ranks is
//! especially important for transformer-based architectures").
//!
//! Given each layer's exact singular spectrum (shipped in checkpoints by
//! `make artifacts`) and a global parameter budget, allocate ranks by
//! greedy marginal utility: repeatedly grant rank increments to the layer
//! with the largest spectral-error reduction *per stored parameter*.
//! Theorem 3.2 motivates the objective: each layer's contribution to
//! output perturbation is governed by its spectral error s_{k+1}, so we
//! minimize Σ_ℓ s_{k_ℓ+1}(ℓ) subject to Σ_ℓ (C_ℓ+D_ℓ)·k_ℓ ≤ budget.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One layer's inputs to the allocator.
#[derive(Debug, Clone)]
pub struct LayerSpectrum {
    pub layer: String,
    pub c: usize,
    pub d: usize,
    /// Exact singular values, descending (length min(c, d)).
    pub spectrum: Vec<f64>,
}

impl LayerSpectrum {
    /// Cost of one unit of rank: C + D parameters.
    fn unit_cost(&self) -> usize {
        self.c + self.d
    }
    /// Error after keeping rank k: s_{k+1} (0 beyond the spectrum).
    fn err_at(&self, k: usize) -> f64 {
        self.spectrum.get(k).copied().unwrap_or(0.0)
    }
    fn max_rank(&self) -> usize {
        self.c.min(self.d)
    }
}

#[derive(Debug)]
struct Candidate {
    layer_idx: usize,
    /// Marginal utility of the next grant: Δerror / Δparams.
    utility: f64,
}

impl PartialEq for Candidate {
    fn eq(&self, other: &Self) -> bool {
        self.utility == other.utility
    }
}
impl Eq for Candidate {}
impl PartialOrd for Candidate {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Candidate {
    fn cmp(&self, other: &Self) -> Ordering {
        self.utility.partial_cmp(&other.utility).unwrap_or(Ordering::Equal)
    }
}

/// Allocate ranks under `budget_ratio` ∈ (0, 1]: the compressed layers may
/// use at most `budget_ratio · Σ C·D` parameters. Every layer gets at
/// least `min_rank`. Grants go in steps of `step` ranks (coarser = faster;
/// 1 = exact greedy).
pub fn allocate_ranks(
    layers: &[LayerSpectrum],
    budget_ratio: f64,
    min_rank: usize,
    step: usize,
) -> Vec<(String, usize)> {
    assert!(budget_ratio > 0.0);
    let step = step.max(1);
    let min_rank = min_rank.max(1);
    let dense_params: usize = layers.iter().map(|l| l.c * l.d).sum();
    let budget = (budget_ratio * dense_params as f64) as usize;

    // Start every layer at min_rank (clamped).
    let mut ranks: Vec<usize> = layers.iter().map(|l| min_rank.min(l.max_rank())).collect();
    let mut spent: usize = layers.iter().zip(&ranks).map(|(l, &k)| l.unit_cost() * k).sum();

    let utility = |l: &LayerSpectrum, k: usize, step: usize| -> f64 {
        let k2 = (k + step).min(l.max_rank());
        if k2 == k {
            return -1.0;
        }
        let gain = l.err_at(k) - l.err_at(k2);
        let cost = (l.unit_cost() * (k2 - k)) as f64;
        gain / cost
    };

    let mut heap: BinaryHeap<Candidate> = layers
        .iter()
        .enumerate()
        .map(|(i, l)| Candidate { layer_idx: i, utility: utility(l, ranks[i], step) })
        .collect();

    while let Some(c) = heap.pop() {
        if c.utility <= 0.0 {
            break;
        }
        let i = c.layer_idx;
        let l = &layers[i];
        // Recompute (heap entries go stale after grants).
        let fresh = utility(l, ranks[i], step);
        if (fresh - c.utility).abs() > 1e-15 {
            if fresh > 0.0 {
                heap.push(Candidate { layer_idx: i, utility: fresh });
            }
            continue;
        }
        let k2 = (ranks[i] + step).min(l.max_rank());
        let cost = l.unit_cost() * (k2 - ranks[i]);
        if spent + cost > budget {
            continue; // this layer's grant doesn't fit; others may
        }
        spent += cost;
        ranks[i] = k2;
        let next = utility(l, ranks[i], step);
        if next > 0.0 {
            heap.push(Candidate { layer_idx: i, utility: next });
        }
    }

    layers.iter().zip(ranks).map(|(l, k)| (l.layer.clone(), k)).collect()
}

/// Total spectral-error proxy Σ s_{k+1} for an allocation (reported by the
/// ablation bench to compare uniform-α vs adaptive).
pub fn total_error(layers: &[LayerSpectrum], ranks: &[(String, usize)]) -> f64 {
    ranks
        .iter()
        .map(|(name, k)| {
            layers.iter().find(|l| &l.layer == name).map(|l| l.err_at(*k)).unwrap_or(0.0)
        })
        .sum()
}

/// Parameter count of an allocation.
pub fn total_params(layers: &[LayerSpectrum], ranks: &[(String, usize)]) -> usize {
    ranks
        .iter()
        .map(|(name, k)| {
            layers.iter().find(|l| &l.layer == name).map(|l| l.unit_cost() * k).unwrap_or(0)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(name: &str, c: usize, d: usize, spec: Vec<f64>) -> LayerSpectrum {
        LayerSpectrum { layer: name.into(), c, d, spectrum: spec }
    }

    fn geometric(n: usize, s0: f64, r: f64) -> Vec<f64> {
        (0..n).map(|i| s0 * r.powi(i as i32)).collect()
    }

    #[test]
    fn respects_budget() {
        let layers = vec![
            layer("a", 64, 256, geometric(64, 10.0, 0.9)),
            layer("b", 64, 64, geometric(64, 5.0, 0.95)),
        ];
        for ratio in [0.1, 0.3, 0.6] {
            let ranks = allocate_ranks(&layers, ratio, 1, 4);
            let dense: usize = layers.iter().map(|l| l.c * l.d).sum();
            let spent = total_params(&layers, &ranks);
            assert!(
                spent as f64 <= ratio * dense as f64 + (64 + 256) as f64 * 4.0,
                "ratio {ratio}: spent {spent}"
            );
            assert!(ranks.iter().all(|(_, k)| *k >= 1));
        }
    }

    #[test]
    fn prefers_slow_decay_layers() {
        // Layer "flat" has a slow-decaying spectrum (needs more rank);
        // "steep" decays fast (cheap to approximate). Same dims.
        let layers = vec![
            layer("flat", 64, 64, geometric(64, 10.0, 0.99)),
            layer("steep", 64, 64, geometric(64, 10.0, 0.5)),
        ];
        let ranks = allocate_ranks(&layers, 0.4, 1, 1);
        let kf = ranks.iter().find(|(n, _)| n == "flat").unwrap().1;
        let ks = ranks.iter().find(|(n, _)| n == "steep").unwrap().1;
        assert!(kf > ks, "flat {kf} should get more rank than steep {ks}");
    }

    #[test]
    fn beats_uniform_alpha_on_heterogeneous_models() {
        // The paper's motivation: transformers have many layers with
        // varying spectra; adaptive allocation should dominate uniform α
        // at equal parameter cost.
        let layers = vec![
            layer("l0", 128, 512, geometric(128, 20.0, 0.995)),
            layer("l1", 128, 128, geometric(128, 8.0, 0.7)),
            layer("l2", 64, 256, geometric(64, 3.0, 0.9)),
            layer("l3", 256, 256, geometric(256, 1.0, 0.999)),
        ];
        let alpha = 0.35;
        let uniform: Vec<(String, usize)> = layers
            .iter()
            .map(|l| (l.layer.clone(), crate::util::rank_for_alpha(alpha, l.c, l.d)))
            .collect();
        let uniform_params = total_params(&layers, &uniform);
        let dense: usize = layers.iter().map(|l| l.c * l.d).sum();
        let adaptive = allocate_ranks(&layers, uniform_params as f64 / dense as f64, 1, 1);
        assert!(
            total_params(&layers, &adaptive) <= uniform_params,
            "adaptive must not exceed the uniform budget"
        );
        let eu = total_error(&layers, &uniform);
        let ea = total_error(&layers, &adaptive);
        assert!(ea < eu, "adaptive error {ea} !< uniform {eu}");
    }

    #[test]
    fn exhausts_useful_spectrum_not_budget() {
        // With a budget beyond (C+D)·max_rank, allocation stops once the
        // spectrum is exhausted (k = max rank), not at the budget. Note
        // ratio > 1 is meaningful here: factored storage can exceed dense
        // (the paper's own α=0.8 rows have ratio 1.02).
        let layers = vec![layer("a", 8, 16, geometric(8, 2.0, 0.5))];
        let ranks = allocate_ranks(&layers, 2.0, 1, 1);
        assert_eq!(ranks[0].1, 8);
        // And a ratio-1.0 budget stops at floor(C·D/(C+D)) = 5.
        let ranks2 = allocate_ranks(&layers, 1.0, 1, 1);
        assert_eq!(ranks2[0].1, 5);
    }

    #[test]
    fn min_rank_clamped_to_layer_size() {
        let layers = vec![layer("tiny", 2, 3, vec![1.0, 0.5])];
        let ranks = allocate_ranks(&layers, 0.9, 10, 1);
        assert_eq!(ranks[0].1, 2);
    }
}
