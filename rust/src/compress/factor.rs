//! The rank-k factorization W ≈ A·B (Section 3: A = Ũ S̃^{1/2},
//! B = S̃^{1/2} Ṽᵀ) plus quality diagnostics.

use crate::linalg::{gemm, norms};
use crate::tensor::Mat;

/// A rank-k factorization of a C×D weight matrix.
#[derive(Debug, Clone)]
pub struct Factorization {
    /// C×k left factor.
    pub a: Mat<f32>,
    /// k×D right factor.
    pub b: Mat<f32>,
    /// Estimated leading singular values (length k, descending).
    pub s: Vec<f64>,
}

impl Factorization {
    pub fn rank(&self) -> usize {
        self.a.cols()
    }

    /// Logical shape (C, D) of the approximated matrix.
    pub fn shape(&self) -> (usize, usize) {
        (self.a.rows(), self.b.cols())
    }

    /// Parameters stored by the factorization: (C + D)·k.
    pub fn param_count(&self) -> usize {
        self.a.rows() * self.a.cols() + self.b.rows() * self.b.cols()
    }

    /// Materialize the dense approximation W̃ = A·B.
    pub fn reconstruct(&self) -> Mat<f32> {
        gemm::matmul(&self.a, &self.b)
    }

    /// ‖W − A·B‖₂ estimated by power iteration without forming W − A·B.
    pub fn spectral_error(&self, w: &Mat<f32>) -> f64 {
        norms::residual_spectral_norm(w, &self.a, &self.b, 300, 1e-9, 0xabcd)
    }

    /// The paper's normalized error ‖W − W̃‖₂ / s_{k+1} given the exact
    /// (k+1)-th singular value.
    pub fn normalized_error(&self, w: &Mat<f32>, s_next: f64) -> f64 {
        norms::normalized_error(self.spectral_error(w), s_next)
    }

    /// Apply to a feature batch: logits = A·(B·Hᵀ) without reconstructing —
    /// the two-small-layers inference rewrite. `h` is N×D (row = sample);
    /// returns N×C.
    pub fn apply(&self, h: &Mat<f32>) -> Mat<f32> {
        // (N×D)·(k×D)ᵀ = N×k, then (N×k)·(C×k)ᵀ = N×C.
        let hk = gemm::matmul_nt(h, &self.b);
        gemm::matmul_nt(&hk, &self.a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::GaussianSource;
    use crate::tensor::init::gaussian;

    fn sample() -> (Mat<f32>, Factorization) {
        let mut g = GaussianSource::new(1);
        let w = gaussian(8, 14, 1.0, &mut g);
        let a = gaussian(8, 3, 0.5, &mut g);
        let b = gaussian(3, 14, 0.5, &mut g);
        (w, Factorization { a, b, s: vec![3.0, 2.0, 1.0] })
    }

    #[test]
    fn shapes_and_counts() {
        let (_, f) = sample();
        assert_eq!(f.rank(), 3);
        assert_eq!(f.shape(), (8, 14));
        assert_eq!(f.param_count(), 8 * 3 + 3 * 14);
    }

    #[test]
    fn apply_matches_reconstruct() {
        let (_, f) = sample();
        let mut g = GaussianSource::new(2);
        let h = gaussian(5, 14, 1.0, &mut g);
        let fast = f.apply(&h);
        let dense = gemm::matmul_nt(&h, &f.reconstruct());
        assert!(fast.sub(&dense).max_abs() < 1e-4);
    }

    #[test]
    fn spectral_error_zero_when_exact() {
        let mut g = GaussianSource::new(3);
        let a = gaussian(6, 2, 1.0, &mut g);
        let b = gaussian(2, 9, 1.0, &mut g);
        let w = gemm::matmul(&a, &b);
        let f = Factorization { a, b, s: vec![] };
        assert!(f.spectral_error(&w) < 1e-4);
    }
}
