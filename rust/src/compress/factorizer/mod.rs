//! The `Factorizer` abstraction: *one* interface for every way this crate
//! can turn a weight matrix into a rank-k factorization.
//!
//! The paper presents RSI as one point in a family of strategies — exact
//! SVD (the optimal baseline), RSVD (q = 1), RSI with varying q and
//! orthonormalization, and the fused whole-algorithm XLA graph. Before
//! this module existed, the pipeline dispatched over a hardcoded
//! `match (Method, BackendKind)`, so every new strategy meant editing the
//! pipeline, the CLI, and the config in lockstep. Now:
//!
//! * [`Factorizer`] — the strategy interface (`factorize` + `name`).
//! * [`ExactSvdFactorizer`] — truncated SVD via the Gram eigensolve.
//! * [`RsiFactorizer`] — Algorithm 3.1 over any [`GemmEngine`].
//! * [`FusedXlaFactorizer`] — the whole-RSI AOT graph; *fails* on shapes
//!   its artifact buckets don't cover, by design.
//! * [`WithFallback`] — explicit composition: try a primary factorizer,
//!   fall back to another on failure. The xla-fused default is
//!   `WithFallback(FusedXlaFactorizer, RsiFactorizer<stepped>)`, making
//!   the old implicit fallback path a visible, testable object.
//! * [`registry::FactorizerRegistry`] — resolves `(Method, BackendKind)`
//!   to a factorizer. Adding a method or backend is one registry entry;
//!   the pipeline never inspects methods or backends again.
//!
//! `compress` stays free of PJRT/runtime types: the fused executor is
//! abstracted as [`FusedRsiExec`] (implemented by
//! `runtime::XlaFusedRsi`), and [`BackendResources`] carries whatever
//! engines the selected backend constructed. See DESIGN.md §Factorizer.

pub mod registry;

pub use registry::{BackendResources, FactorizerRegistry};

use super::backend::GemmEngine;
use super::factor::Factorization;
use super::rsi::{rsi_factorize, RsiOptions};
use crate::linalg::svd::svd_via_gram;
use crate::rng::derive_seed;
use crate::tensor::Mat;
use anyhow::Result;
use std::sync::Arc;

/// A strategy that factors one weight matrix to rank k.
///
/// Implementations must be `Send + Sync`: the pipeline shares one
/// factorizer across all worker threads of a run.
pub trait Factorizer: Send + Sync {
    /// Factor `w` (C×D) to rank `k`. `layer` is the weight's name in the
    /// checkpoint — used to derive per-layer decorrelated sketch seeds and
    /// for error messages.
    fn factorize(&self, w: &Mat<f32>, k: usize, layer: &str) -> Result<Factorization>;

    /// Human-readable strategy name for reports and logs.
    fn name(&self) -> String;
}

/// Executor for the fused whole-Algorithm-3.1 path. Implemented by
/// `runtime::XlaFusedRsi`; kept as a trait so this module (and its tests)
/// never touch PJRT types.
pub trait FusedRsiExec: Send + Sync {
    /// True when a compiled artifact covers this (C, D, k, q) bucket.
    fn supports(&self, c: usize, d: usize, k: usize, q: usize) -> bool;
    /// Run the fused graph and finalize to a rank-k factorization.
    fn factorize(&self, w: &Mat<f32>, k: usize, q: usize, seed: u64) -> Result<Factorization>;
}

/// Exact truncated SVD — the paper's optimal baseline (Eq. 2.3).
#[derive(Debug, Default, Clone, Copy)]
pub struct ExactSvdFactorizer;

impl Factorizer for ExactSvdFactorizer {
    fn factorize(&self, w: &Mat<f32>, k: usize, _layer: &str) -> Result<Factorization> {
        let svd = svd_via_gram(w);
        let (a, b) = svd.factors(k);
        Ok(Factorization { a, b, s: svd.s[..k.min(svd.s.len())].to_vec() })
    }

    fn name(&self) -> String {
        "exact-svd".into()
    }
}

/// Randomized subspace iteration over a pluggable GEMM engine.
///
/// The engine is a type parameter so the native path stays monomorphized
/// (no virtual dispatch in the GEMM hot loop); backends that only exist
/// behind `Arc<dyn GemmEngine>` plug in through the blanket
/// `GemmEngine for Arc<E>` impl.
pub struct RsiFactorizer<E: GemmEngine> {
    opts: RsiOptions,
    engine: E,
}

impl<E: GemmEngine> RsiFactorizer<E> {
    pub fn new(opts: RsiOptions, engine: E) -> Self {
        RsiFactorizer { opts, engine }
    }

    pub fn options(&self) -> &RsiOptions {
        &self.opts
    }
}

impl<E: GemmEngine> Factorizer for RsiFactorizer<E> {
    fn factorize(&self, w: &Mat<f32>, k: usize, layer: &str) -> Result<Factorization> {
        // Per-layer decorrelated sketch seed.
        let mut opts = self.opts;
        opts.seed = derive_seed(opts.seed, layer, 0);
        Ok(rsi_factorize(w, k, &opts, &self.engine))
    }

    fn name(&self) -> String {
        let method = if self.opts.q == 1 {
            "rsvd".to_string()
        } else {
            format!("rsi(q={})", self.opts.q)
        };
        format!("{method}[{}]", self.engine.name())
    }
}

/// Whole Algorithm 3.1 as one compiled graph. Errors when no artifact
/// bucket covers the shape — compose with [`WithFallback`] for the
/// degrade-to-stepped behaviour the pipeline ships by default.
pub struct FusedXlaFactorizer {
    opts: RsiOptions,
    exec: Arc<dyn FusedRsiExec>,
}

impl FusedXlaFactorizer {
    pub fn new(opts: RsiOptions, exec: Arc<dyn FusedRsiExec>) -> Self {
        FusedXlaFactorizer { opts, exec }
    }
}

impl Factorizer for FusedXlaFactorizer {
    fn factorize(&self, w: &Mat<f32>, k: usize, layer: &str) -> Result<Factorization> {
        let (c, d) = w.shape();
        let q = self.opts.q.max(1);
        anyhow::ensure!(
            self.exec.supports(c, d, k, q),
            "no rsi_fused artifact covers ({c},{d},k={k},q={q})"
        );
        let seed = derive_seed(self.opts.seed, layer, 0);
        self.exec.factorize(w, k, q, seed)
    }

    fn name(&self) -> String {
        format!("rsi-fused(q={})", self.opts.q.max(1))
    }
}

/// Explicit fallback composition: run `primary`; on any error, log it and
/// run `fallback`. Replaces the implicit `supports()` branch the pipeline
/// used to hide inside its dispatch `match`.
pub struct WithFallback {
    primary: Arc<dyn Factorizer>,
    fallback: Arc<dyn Factorizer>,
}

impl WithFallback {
    pub fn new(primary: Arc<dyn Factorizer>, fallback: Arc<dyn Factorizer>) -> Self {
        WithFallback { primary, fallback }
    }
}

impl Factorizer for WithFallback {
    fn factorize(&self, w: &Mat<f32>, k: usize, layer: &str) -> Result<Factorization> {
        match self.primary.factorize(w, k, layer) {
            Ok(f) => Ok(f),
            Err(e) => {
                // Visible by default: a genuine primary-path failure
                // (not just missing artifact coverage) that degrades to
                // the fallback must not hide at debug level, or a broken
                // fused deployment just looks mysteriously slow.
                log::warn!(
                    "{layer}: {} failed ({e:#}); falling back to {}",
                    self.primary.name(),
                    self.fallback.name()
                );
                self.fallback.factorize(w, k, layer)
            }
        }
    }

    fn name(&self) -> String {
        format!("{}→{}", self.primary.name(), self.fallback.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::backend::NativeEngine;
    use crate::rng::GaussianSource;
    use crate::tensor::init::{matrix_with_spectrum, SpectrumShape};

    fn test_matrix(c: usize, d: usize, seed: u64) -> Mat<f32> {
        let mut g = GaussianSource::new(seed);
        let spec = SpectrumShape::pretrained_like().values(c.min(d));
        matrix_with_spectrum(c, d, &spec, &mut g)
    }

    #[test]
    fn exact_svd_factorizer_matches_direct_svd() {
        let w = test_matrix(20, 36, 1);
        let k = 5;
        let f = ExactSvdFactorizer.factorize(&w, k, "layers.0").unwrap();
        assert_eq!(f.rank(), k);
        let svd = svd_via_gram(&w);
        // SVD is optimal: error equals s_{k+1} (up to estimator noise).
        let err = f.spectral_error(&w);
        let rel = (err - svd.s[k]).abs() / svd.s[k].max(1e-12);
        assert!(rel < 0.05, "err {err} vs s_k+1 {}", svd.s[k]);
    }

    #[test]
    fn rsi_factorizer_derives_per_layer_seeds() {
        let w = test_matrix(24, 48, 2);
        let fz = RsiFactorizer::new(RsiOptions::with_q(2, 7), NativeEngine);
        let f0 = fz.factorize(&w, 6, "layers.0").unwrap();
        let f0_again = fz.factorize(&w, 6, "layers.0").unwrap();
        let f1 = fz.factorize(&w, 6, "layers.1").unwrap();
        // Deterministic per layer, decorrelated across layers.
        assert_eq!(f0.a, f0_again.a);
        assert_ne!(f0.a, f1.a);
    }

    #[test]
    fn rsi_factorizer_over_dyn_engine() {
        let w = test_matrix(16, 30, 3);
        let engine: Arc<dyn GemmEngine> = Arc::new(NativeEngine);
        let fz = RsiFactorizer::new(RsiOptions::with_q(2, 3), engine);
        let f = fz.factorize(&w, 4, "l").unwrap();
        assert_eq!(f.rank(), 4);
        assert!(fz.name().contains("native"));
    }

    struct NeverFused;
    impl FusedRsiExec for NeverFused {
        fn supports(&self, _c: usize, _d: usize, _k: usize, _q: usize) -> bool {
            false
        }
        fn factorize(&self, _w: &Mat<f32>, _k: usize, _q: usize, _seed: u64) -> Result<Factorization> {
            anyhow::bail!("unreachable: supports() is false")
        }
    }

    #[test]
    fn fused_errors_without_coverage_and_fallback_recovers() {
        let w = test_matrix(12, 20, 4);
        let opts = RsiOptions::with_q(2, 11);
        let fused = FusedXlaFactorizer::new(opts, Arc::new(NeverFused));
        assert!(fused.factorize(&w, 3, "l").is_err());

        let composed = WithFallback::new(
            Arc::new(FusedXlaFactorizer::new(opts, Arc::new(NeverFused))),
            Arc::new(RsiFactorizer::new(opts, NativeEngine)),
        );
        let f = composed.factorize(&w, 3, "l").unwrap();
        assert_eq!(f.rank(), 3);
        // Fallback result is exactly the stepped path's result.
        let direct = RsiFactorizer::new(opts, NativeEngine).factorize(&w, 3, "l").unwrap();
        assert_eq!(f.a, direct.a);
        assert!(composed.name().contains("→"));
    }
}
