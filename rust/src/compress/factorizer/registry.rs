//! Resolving `(Method, BackendKind)` → [`Factorizer`].
//!
//! The registry is a list of `(method key, backend, builder)` entries.
//! Resolution prefers an exact backend match, then a wildcard entry
//! (`backend: None`) — exact SVD, for example, is backend-agnostic and
//! registers once as a wildcard. Builders receive the concrete [`Method`]
//! (for its options) and the [`BackendResources`] the pipeline
//! constructed for its backend, and return a shareable factorizer.
//!
//! Adding a new method end-to-end:
//!
//! 1. implement [`Factorizer`] in this module (one file),
//! 2. register a builder under a key,
//! 3. plan with `Method::Custom("key")` (or a new `Method` variant if it
//!    carries options).
//!
//! The pipeline, CLI, and config never change.

use super::{
    ExactSvdFactorizer, Factorizer, FusedRsiExec, FusedXlaFactorizer, RsiFactorizer, WithFallback,
};
use crate::compress::backend::{BackendKind, GemmEngine, NativeEngine};
use crate::compress::plan::Method;
use crate::compress::rsi::RsiOptions;
use anyhow::{Context, Result};
use std::sync::Arc;

/// Engines the selected backend constructed; consumed by builders.
/// `Native` needs nothing; the XLA backends populate both fields.
#[derive(Default, Clone)]
pub struct BackendResources {
    /// Stepped-GEMM engine (Algorithm 3.1's lines 3/5 off-loaded).
    pub gemm: Option<Arc<dyn GemmEngine>>,
    /// Whole-algorithm fused executor.
    pub fused: Option<Arc<dyn FusedRsiExec>>,
}

type Builder =
    Box<dyn Fn(&Method, &BackendResources) -> Result<Arc<dyn Factorizer>> + Send + Sync>;

struct Entry {
    method: String,
    /// `None` = any backend (used when no exact match exists).
    backend: Option<BackendKind>,
    build: Builder,
}

/// Maps `(Method::key(), BackendKind)` to factorizer builders.
pub struct FactorizerRegistry {
    entries: Vec<Entry>,
}

impl Default for FactorizerRegistry {
    fn default() -> Self {
        Self::with_defaults()
    }
}

impl FactorizerRegistry {
    /// An empty registry (tests / fully custom setups).
    pub fn new() -> Self {
        FactorizerRegistry { entries: Vec::new() }
    }

    /// The shipped strategy family: exact SVD (any backend), RSI on the
    /// native and stepped-XLA engines, and fused-XLA with explicit
    /// fallback to stepped.
    pub fn with_defaults() -> Self {
        let mut r = Self::new();
        r.register("svd", None, |_m, _res| Ok(Arc::new(ExactSvdFactorizer)));
        r.register("rsi", Some(BackendKind::Native), |m, _res| {
            Ok(Arc::new(RsiFactorizer::new(rsi_opts(m)?, NativeEngine)))
        });
        r.register("rsi", Some(BackendKind::XlaStepped), |m, res| {
            let gemm = res.gemm.clone().context("xla-stepped backend without a GEMM engine")?;
            Ok(Arc::new(RsiFactorizer::new(rsi_opts(m)?, gemm)))
        });
        r.register("rsi", Some(BackendKind::XlaFused), |m, res| {
            let opts = rsi_opts(m)?;
            let fused = res.fused.clone().context("xla-fused backend without a fused executor")?;
            let gemm = res.gemm.clone().context("xla-fused backend without a GEMM engine")?;
            Ok(Arc::new(WithFallback::new(
                Arc::new(FusedXlaFactorizer::new(opts, fused)),
                Arc::new(RsiFactorizer::new(opts, gemm)),
            )))
        });
        r
    }

    /// Register a builder for `method` (a [`Method::key`] value) on
    /// `backend`, or on any backend when `backend` is `None`. Later
    /// registrations shadow earlier ones with the same key.
    pub fn register<F>(&mut self, method: impl Into<String>, backend: Option<BackendKind>, build: F)
    where
        F: Fn(&Method, &BackendResources) -> Result<Arc<dyn Factorizer>> + Send + Sync + 'static,
    {
        self.entries.insert(
            0,
            Entry { method: method.into(), backend, build: Box::new(build) },
        );
    }

    /// Resolve a factorizer for this method/backend pair. Entries are
    /// scanned newest-first and an entry matches when its backend is the
    /// requested one *or* a wildcard — so the most recent registration
    /// for a key always wins, including a wildcard registered over the
    /// per-backend defaults.
    pub fn resolve(
        &self,
        method: &Method,
        backend: BackendKind,
        resources: &BackendResources,
    ) -> Result<Arc<dyn Factorizer>> {
        let key = method.key();
        let entry = self
            .entries
            .iter()
            .find(|e| e.method == key && (e.backend == Some(backend) || e.backend.is_none()))
            .with_context(|| {
                format!(
                    "no factorizer registered for method {key:?} on backend {:?} (known: {})",
                    backend.name(),
                    self.known_methods().join(", ")
                )
            })?;
        (entry.build)(method, resources)
    }

    /// Distinct registered method keys (diagnostics).
    pub fn known_methods(&self) -> Vec<String> {
        let mut keys: Vec<String> = self.entries.iter().map(|e| e.method.clone()).collect();
        keys.sort();
        keys.dedup();
        keys
    }
}

fn rsi_opts(m: &Method) -> Result<RsiOptions> {
    match m {
        Method::Rsi(o) => Ok(*o),
        other => anyhow::bail!("RSI factorizer builder got non-RSI method {:?}", other.name()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::factor::Factorization;
    use crate::tensor::Mat;

    #[test]
    fn defaults_cover_the_shipped_family() {
        let reg = FactorizerRegistry::with_defaults();
        let res = BackendResources::default();
        let rsi = reg
            .resolve(&Method::Rsi(RsiOptions::with_q(3, 1)), BackendKind::Native, &res)
            .unwrap();
        assert!(rsi.name().contains("rsi(q=3)"));
        // SVD resolves on every backend through the wildcard entry.
        for b in [BackendKind::Native, BackendKind::XlaStepped, BackendKind::XlaFused] {
            let svd = reg.resolve(&Method::ExactSvd, b, &res).unwrap();
            assert_eq!(svd.name(), "exact-svd");
        }
    }

    #[test]
    fn xla_entries_demand_resources() {
        let reg = FactorizerRegistry::with_defaults();
        let method = Method::Rsi(RsiOptions::default());
        let empty = BackendResources::default();
        assert!(reg.resolve(&method, BackendKind::XlaStepped, &empty).is_err());
        assert!(reg.resolve(&method, BackendKind::XlaFused, &empty).is_err());
    }

    #[test]
    fn unknown_method_lists_known_keys() {
        let reg = FactorizerRegistry::with_defaults();
        let err = reg
            .resolve(&Method::Custom("anchored-svd"), BackendKind::Native, &Default::default())
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("anchored-svd"), "{msg}");
        assert!(msg.contains("rsi"), "{msg}");
    }

    struct Doubler;
    impl Factorizer for Doubler {
        fn factorize(&self, w: &Mat<f32>, k: usize, _layer: &str) -> anyhow::Result<Factorization> {
            let (c, d) = w.shape();
            Ok(Factorization { a: Mat::zeros(c, k), b: Mat::zeros(k, d), s: vec![0.0; k] })
        }
        fn name(&self) -> String {
            "doubler".into()
        }
    }

    #[test]
    fn custom_registration_and_shadowing() {
        let mut reg = FactorizerRegistry::with_defaults();
        reg.register("doubler", None, |_m, _r| Ok(Arc::new(Doubler)));
        let f = reg
            .resolve(&Method::Custom("doubler"), BackendKind::Native, &Default::default())
            .unwrap();
        assert_eq!(f.name(), "doubler");
        // Shadow the default svd entry: later registrations win.
        reg.register("svd", None, |_m, _r| Ok(Arc::new(Doubler)));
        let f = reg.resolve(&Method::ExactSvd, BackendKind::Native, &Default::default()).unwrap();
        assert_eq!(f.name(), "doubler");
        // A later *wildcard* also shadows earlier per-backend defaults —
        // the natural way to globally replace a shipped strategy.
        reg.register("rsi", None, |_m, _r| Ok(Arc::new(Doubler)));
        let f = reg
            .resolve(&Method::Rsi(RsiOptions::default()), BackendKind::Native, &Default::default())
            .unwrap();
        assert_eq!(f.name(), "doubler");
    }
}
