//! Randomized subspace iteration — Algorithm 3.1 of the paper.
//!
//! ```text
//! Require: W ∈ R^{C×D}, target rank k, iteration count q ≥ 1
//! 1: draw Ω ∈ R^{D×k}; Y = Ω
//! 2: for t = 1..q:
//! 3:     X = W·Y
//! 4:     [X, _] = qr(X)
//! 5:     Y = Wᵀ·X
//! 6: end
//! 7: [Û, S̃, Ṽ] = svd(Yᵀ)
//! 8: Ũ = X·Û
//! 9: return Ũ, S̃, Ṽ
//! ```
//!
//! q = 1 is exactly RSVD (Section 2); q > 1 amplifies spectral separation
//! with singular values raised to the (2q−1)-th power (Eq. 3.2).
//!
//! The GEMMs on lines 3 and 5 run through a [`GemmEngine`]; the
//! orthonormalization on line 4 is pluggable ([`OrthoStrategy`]) because
//! the TPU-shaped fused artifact replaces Householder QR with the
//! matmul-only Newton–Schulz iteration (see DESIGN.md §Hardware-Adaptation).
//! The final small SVD (line 7) is computed from the ℓ×ℓ Gram of Y — the
//! only dense eigenproblem, solved by our cyclic-Jacobi `eigh`.

use super::backend::GemmEngine;
use super::factor::Factorization;
use crate::linalg::{chol, eigh, gemm, qr};
use crate::rng::GaussianSource;
use crate::tensor::Mat;

/// How line 4's orthonormalization runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OrthoStrategy {
    /// Householder thin QR (the paper's `qr()`, reference behaviour).
    Householder,
    /// CholeskyQR2 — GEMM-rich; falls back to Householder when the Gram
    /// matrix goes numerically indefinite.
    CholeskyQr2,
    /// Newton–Schulz inverse-square-root iteration (matmuls only; what the
    /// fused XLA artifact uses). The value is the iteration count.
    NewtonSchulz(usize),
}

/// Newton–Schulz iteration count used when none is given explicitly.
pub const DEFAULT_NS_ITERS: usize = 12;

impl OrthoStrategy {
    /// Parse a strategy name. Newton–Schulz accepts an explicit iteration
    /// count as `ns:N` / `newtonschulz:N` (N ≥ 1); the bare names use
    /// [`DEFAULT_NS_ITERS`].
    pub fn parse(s: &str) -> Option<Self> {
        let t = s.to_ascii_lowercase();
        if let Some((head, count)) = t.split_once(':') {
            return match head.trim() {
                "newtonschulz" | "ns" => count
                    .trim()
                    .parse::<usize>()
                    .ok()
                    .filter(|&n| n >= 1)
                    .map(OrthoStrategy::NewtonSchulz),
                _ => None,
            };
        }
        match t.as_str() {
            "householder" | "qr" => Some(OrthoStrategy::Householder),
            "choleskyqr2" | "cholqr2" | "cholesky" => Some(OrthoStrategy::CholeskyQr2),
            "newtonschulz" | "ns" => Some(OrthoStrategy::NewtonSchulz(DEFAULT_NS_ITERS)),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            OrthoStrategy::Householder => "householder",
            OrthoStrategy::CholeskyQr2 => "choleskyqr2",
            OrthoStrategy::NewtonSchulz(_) => "newton-schulz",
        }
    }
}

/// RSI options (Algorithm 3.1 inputs beyond W and k).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RsiOptions {
    /// Power-iteration count q ≥ 1; q = 1 ⇒ RSVD.
    pub q: usize,
    /// Extra sketch columns beyond k (oversampling p; paper uses 0).
    pub oversample: usize,
    /// Line-4 orthonormalization strategy.
    pub ortho: OrthoStrategy,
    /// Seed for Ω.
    pub seed: u64,
}

impl Default for RsiOptions {
    fn default() -> Self {
        RsiOptions { q: 2, oversample: 0, ortho: OrthoStrategy::Householder, seed: 0 }
    }
}

impl RsiOptions {
    /// The paper's RSVD baseline (q = 1).
    pub fn rsvd(seed: u64) -> Self {
        RsiOptions { q: 1, seed, ..Default::default() }
    }

    pub fn with_q(q: usize, seed: u64) -> Self {
        RsiOptions { q: q.max(1), seed, ..Default::default() }
    }
}

/// Orthonormalize the columns of X per the selected strategy.
pub fn orthonormalize(x: &Mat<f32>, strategy: OrthoStrategy) -> Mat<f32> {
    match strategy {
        OrthoStrategy::Householder => qr::orthonormalize(x),
        OrthoStrategy::CholeskyQr2 => match chol::cholesky_qr2(x) {
            Ok((q, _)) => q,
            Err(_) => qr::orthonormalize(x), // indefinite Gram → robust path
        },
        OrthoStrategy::NewtonSchulz(iters) => newton_schulz_ortho(x, iters),
    }
}

/// Newton–Schulz orthonormalization: Q = X·(XᵀX)^{-1/2} computed with
/// matmuls only. Converges when the spectrum of G/τ lies in (0, 2);
/// we scale by τ = tr(G) which guarantees it for full-rank X.
///
/// This is the TPU-friendly substitute for line 4: on a systolic array the
/// k×k iteration stays on the MXU, while Householder QR serializes.
pub fn newton_schulz_ortho(x: &Mat<f32>, iters: usize) -> Mat<f32> {
    let g64 = gemm::gram_tn_f64(x); // ℓ×ℓ
    let l = x.cols();
    let trace: f64 = (0..l).map(|i| g64.get(i, i)).sum();
    if trace <= 0.0 {
        return x.clone();
    }
    // Work in f64 for the small iteration; cost O(ℓ³) per iter.
    let mut gs = g64.clone();
    gs.scale(1.0 / trace);
    // Z ≈ (G/τ)^{-1/2} via coupled Newton–Schulz:
    //   Y_{t+1} = Y_t (3I − Z_t Y_t)/2,  Z_{t+1} = (3I − Z_t Y_t)/2 Z_t
    // with Y₀ = G/τ, Z₀ = I; then (G)^{-1/2} = Z_∞ / √τ.
    let mut y = gs.clone();
    let mut z = Mat::<f64>::eye(l);
    for _ in 0..iters {
        // T = (3I − Z·Y)/2
        let zy = gemm::matmul(&z, &y);
        let mut t = Mat::<f64>::eye(l);
        t.scale(3.0);
        t.axpy(-1.0, &zy);
        t.scale(0.5);
        y = gemm::matmul(&y, &t);
        z = gemm::matmul(&t, &z);
    }
    z.scale(1.0 / trace.sqrt());
    // Q = X · G^{-1/2}.
    gemm::matmul(x, &z.cast::<f32>())
}

/// Run Algorithm 3.1 and return the rank-k factorization
/// (A = Ũ_k S̃_k^{1/2}, B = S̃_k^{1/2} Ṽ_kᵀ) plus the estimated spectrum.
pub fn rsi_factorize(
    w: &Mat<f32>,
    k: usize,
    opts: &RsiOptions,
    engine: &dyn GemmEngine,
) -> Factorization {
    let (c, d) = w.shape();
    let k = k.clamp(1, c.min(d));
    let l = (k + opts.oversample).min(c.min(d)); // sketch width ℓ
    let q = opts.q.max(1);

    // Line 1: Ω ∈ R^{D×ℓ}.
    let mut gsrc = GaussianSource::new(opts.seed);
    let mut y = Mat::from_vec(d, l, gsrc.matrix_f32(d, l));

    // Telemetry reads the iterates, never writes them: X and Y evolve
    // bit-identically with obs on or off.
    if crate::obs::enabled() {
        crate::obs::compress::stage_begin();
    }

    // Lines 2–6.
    let mut x = Mat::zeros(c, l);
    for _t in 0..q {
        x = engine.wy(w, &y); // line 3: X = W·Y
        x = orthonormalize(&x, opts.ortho); // line 4
        y = engine.wtx(w, &x); // line 5: Y = Wᵀ·X
        if crate::obs::enabled() {
            crate::obs::compress::stage_iteration(captured_mass(&y));
        }
    }

    finalize(&x, &y, k)
}

/// Convergence signal per power iteration: ‖WᵀXₜ‖_F = √(Σ‖yⱼ‖²), the
/// spectral mass the current subspace captures. With X's columns
/// orthonormal this climbs toward √(σ₁²+…+σ_ℓ²) as the subspace locks
/// onto the leading singular directions — a plateau means converged.
fn captured_mass(y: &Mat<f32>) -> f64 {
    let mut acc = 0.0f64;
    for &v in y.data() {
        acc += (v as f64) * (v as f64);
    }
    acc.sqrt()
}

/// Above this sketch width the ℓ×ℓ Jacobi eigensolve in [`finalize`]
/// dominates the whole factorization, so when no truncation is needed
/// (ℓ == k) we return the equivalent split A = X, B = Yᵀ directly:
/// X·Yᵀ = X·(Û S̃ Ṽᵀ) = Ũ S̃ Ṽᵀ — the *same matrix* the SVD-completed
/// factors multiply to, skipping the O(ℓ³) eigensolve. Singular values
/// are then estimated from Y's column norms (exact when X converged).
const FAST_SPLIT_THRESHOLD: usize = 384;

/// Lines 7–9: SVD of Yᵀ (ℓ×D) via its ℓ×ℓ Gram, then Ũ = X·Û; truncate
/// to rank k and split into balanced factors.
pub fn finalize(x: &Mat<f32>, y: &Mat<f32>, k: usize) -> Factorization {
    let l = x.cols();
    debug_assert_eq!(y.cols(), l);
    let k = k.min(l);
    if l == k && l > FAST_SPLIT_THRESHOLD {
        return finalize_fast_split(x, y);
    }

    // Gram of the columns of Y: G = YᵀY = (Yᵀ)(Yᵀ)ᵀ, ℓ×ℓ, f64.
    let g = gemm::gram_tn_f64(y);
    let e = eigh::eigh_default(&g);
    // Singular values of Yᵀ are √λ.
    let s: Vec<f64> = e.values.iter().map(|&v| v.max(0.0).sqrt()).collect();
    // The full ℓ-length spectrum exists only here, before truncation:
    // σ_{k+1} (the gap's far side) is observable exactly when the
    // sketch oversampled (ℓ > k).
    if k >= 1 && crate::obs::enabled() {
        crate::obs::compress::stage_spectrum(s[k - 1], s.get(k).copied().unwrap_or(0.0));
    }
    let uhat = e.vectors.cast::<f32>(); // ℓ×ℓ: left singular vectors of Yᵀ

    // Ṽ = Y · Û S⁻¹ (D×ℓ): right singular vectors of Yᵀ.
    let cutoff = 1e-7 * s.first().copied().unwrap_or(0.0);
    let mut us_inv = uhat.clone();
    for cix in 0..l {
        let inv = if s[cix] > cutoff { (1.0 / s[cix]) as f32 } else { 0.0 };
        for r in 0..l {
            let v = us_inv.get(r, cix) * inv;
            us_inv.set(r, cix, v);
        }
    }
    let vt_full = gemm::matmul(y, &us_inv); // D×ℓ

    // Ũ = X·Û (C×ℓ).
    let u_full = gemm::matmul(x, &uhat);

    // Truncate to k and build balanced factors A = Ũ√S, B = √S Ṽᵀ.
    let mut a = u_full.cols_range(0, k);
    let vk = vt_full.cols_range(0, k); // D×k
    let mut b = vk.transpose(); // k×D
    for cix in 0..k {
        let sq = s[cix].sqrt() as f32;
        for r in 0..a.rows() {
            let v = a.get(r, cix) * sq;
            a.set(r, cix, v);
        }
        for j in 0..b.cols() {
            let v = b.get(cix, j) * sq;
            b.set(cix, j, v);
        }
    }
    Factorization { a, b, s: s[..k].to_vec() }
}

/// ℓ == k fast path: A = X (orthonormal), B = Yᵀ. Reconstruction is
/// bit-identical in exact arithmetic to the SVD-completed factors; only
/// the internal balance differs. Singular-value estimates come from Y's
/// column norms (‖y_j‖ = s̃_j when X's columns are the converged singular
/// directions). The descending sort is applied as a *joint* permutation of
/// (s, A's columns, B's rows), so `s[i]` always describes factor column
/// `i` — sorting the estimates alone would silently decouple them from
/// the factors.
fn finalize_fast_split(x: &Mat<f32>, y: &Mat<f32>) -> Factorization {
    let l = x.cols();
    let norms: Vec<f64> = (0..l)
        .map(|j| {
            let mut acc = 0.0f64;
            for r in 0..y.rows() {
                let v = y.get(r, j) as f64;
                acc += v * v;
            }
            acc.sqrt()
        })
        .collect();
    let mut perm: Vec<usize> = (0..l).collect();
    perm.sort_by(|&i, &j| {
        norms[j].partial_cmp(&norms[i]).unwrap_or(std::cmp::Ordering::Equal)
    });
    let (c, d) = (x.rows(), y.rows());
    let mut a = Mat::zeros(c, l);
    let mut b = Mat::zeros(l, d);
    for (new_j, &old_j) in perm.iter().enumerate() {
        for r in 0..c {
            a.set(r, new_j, x.get(r, old_j));
        }
        for col in 0..d {
            b.set(new_j, col, y.get(col, old_j));
        }
    }
    let s: Vec<f64> = perm.iter().map(|&j| norms[j]).collect();
    // ℓ == k on this path: no oversampling column exists, so σ_{k+1}
    // is unobservable (reported as 0).
    if crate::obs::enabled() {
        crate::obs::compress::stage_spectrum(s.last().copied().unwrap_or(0.0), 0.0);
    }
    Factorization { a, b, s }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::backend::NativeEngine;
    use crate::linalg::svd::svd_via_gram;
    use crate::tensor::init::{matrix_with_spectrum, SpectrumShape};

    fn slow_decay_matrix(c: usize, d: usize, seed: u64) -> (Mat<f32>, Vec<f64>) {
        let mut g = GaussianSource::new(seed);
        let spec = SpectrumShape::pretrained_like().values(c);
        let w = matrix_with_spectrum(c, d, &spec, &mut g);
        (w, spec)
    }

    #[test]
    fn q1_is_rsvd_and_error_above_optimal() {
        // RSI error can never beat s_{k+1} (SVD optimality, Eq. 2.3).
        let (w, spec) = slow_decay_matrix(48, 120, 1);
        let k = 8;
        let f = rsi_factorize(&w, k, &RsiOptions::rsvd(7), &NativeEngine);
        assert_eq!(f.rank(), k);
        let err = f.spectral_error(&w);
        assert!(err >= spec[k] * 0.999, "err {err} < s_k+1 {}", spec[k]);
    }

    #[test]
    fn error_decreases_with_q() {
        // The paper's core claim (Fig 4.1a): more power iterations →
        // better approximation in the slow-decay regime.
        let (w, spec) = slow_decay_matrix(64, 160, 2);
        let k = 10;
        let mut errs = Vec::new();
        for q in [1usize, 2, 4] {
            // Average over a few sketches to avoid fluke orderings.
            let mut acc = 0.0;
            for trial in 0..3u64 {
                let opts = RsiOptions::with_q(q, 100 + trial);
                let f = rsi_factorize(&w, k, &opts, &NativeEngine);
                acc += f.spectral_error(&w);
            }
            errs.push(acc / 3.0);
        }
        assert!(
            errs[0] > errs[1] && errs[1] > errs[2] * 0.999,
            "errors not decreasing with q: {errs:?}"
        );
        // And q=4 should be near-optimal (normalized error close to 1,
        // paper reports ≈1.1).
        let norm_err = errs[2] / spec[k];
        assert!(norm_err < 1.6, "q=4 normalized error {norm_err} too high");
    }

    #[test]
    fn exact_on_low_rank_input() {
        // If rank(W) ≤ k, RSI recovers W (up to fp noise) for any q.
        let mut g = GaussianSource::new(3);
        let u = crate::tensor::init::gaussian(20, 4, 1.0, &mut g);
        let v = crate::tensor::init::gaussian(4, 35, 1.0, &mut g);
        let w = gemm::matmul(&u, &v);
        for q in [1usize, 3] {
            let f = rsi_factorize(&w, 4, &RsiOptions::with_q(q, 5), &NativeEngine);
            let err = f.reconstruct().sub(&w).max_abs();
            assert!(err < 1e-3, "q={q}: err {err}");
        }
    }

    #[test]
    fn singular_value_estimates_improve_with_q() {
        let (w, spec) = slow_decay_matrix(40, 100, 4);
        let k = 6;
        let f1 = rsi_factorize(&w, k, &RsiOptions::with_q(1, 9), &NativeEngine);
        let f4 = rsi_factorize(&w, k, &RsiOptions::with_q(4, 9), &NativeEngine);
        // Estimated s₁ should be ≤ true s₁ and tighter for larger q.
        assert!(f4.s[0] <= spec[0] * 1.001);
        let gap1 = (spec[0] - f1.s[0]).abs();
        let gap4 = (spec[0] - f4.s[0]).abs();
        assert!(gap4 <= gap1 + 1e-9, "s1 gap should shrink: q1 {gap1} q4 {gap4}");
    }

    #[test]
    fn ortho_strategies_agree_on_well_conditioned() {
        let (w, _) = slow_decay_matrix(32, 80, 5);
        let k = 6;
        let mk = |ortho| {
            let opts = RsiOptions { q: 2, oversample: 0, ortho, seed: 11 };
            rsi_factorize(&w, k, &opts, &NativeEngine).spectral_error(&w)
        };
        let eh = mk(OrthoStrategy::Householder);
        let ec = mk(OrthoStrategy::CholeskyQr2);
        let en = mk(OrthoStrategy::NewtonSchulz(16));
        // Same sketch seed → all three should land on near-identical errors.
        assert!((eh - ec).abs() / eh < 0.02, "householder {eh} vs cholqr2 {ec}");
        assert!((eh - en).abs() / eh < 0.05, "householder {eh} vs ns {en}");
    }

    #[test]
    fn newton_schulz_orthogonality() {
        let mut g = GaussianSource::new(6);
        let x = crate::tensor::init::gaussian(50, 8, 1.0, &mut g);
        let q = newton_schulz_ortho(&x, 20);
        let err = qr::ortho_error(&q);
        assert!(err < 1e-3, "NS ortho error {err}");
    }

    #[test]
    fn oversampling_helps_rsvd() {
        let (w, _) = slow_decay_matrix(48, 120, 7);
        let k = 8;
        let plain = RsiOptions { q: 1, oversample: 0, ortho: OrthoStrategy::Householder, seed: 3 };
        let over = RsiOptions { q: 1, oversample: 8, ortho: OrthoStrategy::Householder, seed: 3 };
        let mut e_plain = 0.0;
        let mut e_over = 0.0;
        for t in 0..3u64 {
            let mut p = plain;
            p.seed = 3 + t;
            let mut o = over;
            o.seed = 3 + t;
            e_plain += rsi_factorize(&w, k, &p, &NativeEngine).spectral_error(&w);
            e_over += rsi_factorize(&w, k, &o, &NativeEngine).spectral_error(&w);
        }
        assert!(e_over < e_plain, "oversampling should reduce error: {e_over} vs {e_plain}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (w, _) = slow_decay_matrix(24, 50, 8);
        let opts = RsiOptions::with_q(2, 42);
        let f1 = rsi_factorize(&w, 5, &opts, &NativeEngine);
        let f2 = rsi_factorize(&w, 5, &opts, &NativeEngine);
        assert_eq!(f1.a, f2.a);
        assert_eq!(f1.b, f2.b);
    }

    #[test]
    fn agrees_with_exact_svd_when_q_large() {
        // With many iterations the subspace converges to the exact one.
        let (w, _) = slow_decay_matrix(30, 70, 9);
        let k = 5;
        let svd = svd_via_gram(&w);
        let f = rsi_factorize(&w, k, &RsiOptions::with_q(8, 13), &NativeEngine);
        let optimal = svd.s[k];
        let err = f.spectral_error(&w);
        assert!(err / optimal < 1.15, "q=8 err {err} vs optimal {optimal}");
        // Singular value estimates match the exact leading spectrum.
        for i in 0..k {
            crate::testutil::assert_relclose(f.s[i], svd.s[i], 0.05, "s_i");
        }
    }

    #[test]
    fn ortho_strategy_parse() {
        assert_eq!(OrthoStrategy::parse("qr"), Some(OrthoStrategy::Householder));
        assert_eq!(OrthoStrategy::parse("Householder"), Some(OrthoStrategy::Householder));
        assert_eq!(OrthoStrategy::parse("cholqr2"), Some(OrthoStrategy::CholeskyQr2));
        // Bare Newton–Schulz names use the default iteration count…
        assert_eq!(OrthoStrategy::parse("ns"), Some(OrthoStrategy::NewtonSchulz(DEFAULT_NS_ITERS)));
        assert_eq!(
            OrthoStrategy::parse("newtonschulz"),
            Some(OrthoStrategy::NewtonSchulz(DEFAULT_NS_ITERS))
        );
        // …while `ns:N` / `newtonschulz:N` set it explicitly.
        assert_eq!(OrthoStrategy::parse("ns:20"), Some(OrthoStrategy::NewtonSchulz(20)));
        assert_eq!(OrthoStrategy::parse("NS:4"), Some(OrthoStrategy::NewtonSchulz(4)));
        assert_eq!(OrthoStrategy::parse("newtonschulz:8"), Some(OrthoStrategy::NewtonSchulz(8)));
        assert_eq!(OrthoStrategy::parse("ns: 6"), Some(OrthoStrategy::NewtonSchulz(6)));
        // Invalid counts and hosts are rejected.
        assert_eq!(OrthoStrategy::parse("ns:0"), None);
        assert_eq!(OrthoStrategy::parse("ns:abc"), None);
        assert_eq!(OrthoStrategy::parse("ns:"), None);
        assert_eq!(OrthoStrategy::parse("qr:3"), None);
        assert_eq!(OrthoStrategy::parse("warp"), None);
    }

    #[test]
    fn fast_split_factor_columns_follow_sorted_spectrum() {
        // Regression: finalize_fast_split used to sort the singular-value
        // estimates while leaving A's columns / B's rows in sketch order,
        // so f.s[i] stopped describing factor column i. Build an (X, Y)
        // pair whose column norms arrive deliberately out of order and
        // check the joint permutation.
        let (c, d, l) = (30, 40, 4);
        let mut g = GaussianSource::new(33);
        let x = qr::orthonormalize(&crate::tensor::init::gaussian(c, l, 1.0, &mut g));
        let v = qr::orthonormalize(&crate::tensor::init::gaussian(d, l, 1.0, &mut g));
        let s_true = [2.0f64, 5.0, 1.0, 4.0]; // unsorted on purpose
        let mut y = v.clone();
        for j in 0..l {
            for r in 0..d {
                let val = y.get(r, j) * s_true[j] as f32;
                y.set(r, j, val);
            }
        }

        let fast = finalize_fast_split(&x, &y);
        let full = finalize(&x, &y, l);

        // Reconstruction must equal X·Yᵀ on both paths (the permutation
        // cancels between A and B).
        let want = gemm::matmul(&x, &y.transpose());
        assert!(fast.reconstruct().sub(&want).max_abs() < 1e-4);
        assert!(full.reconstruct().sub(&want).max_abs() < 1e-3);

        // Spectra agree with the SVD-completed path and come out sorted.
        for i in 0..l {
            crate::testutil::assert_relclose(fast.s[i], full.s[i], 1e-3, "s_i fast vs full");
        }
        let mut sorted = s_true.to_vec();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        for i in 0..l {
            crate::testutil::assert_relclose(fast.s[i], sorted[i], 1e-3, "s_i sorted");
        }

        // The regression check: column i of the factors carries s[i].
        // A's columns are orthonormal, so ‖B row i‖ must equal s[i].
        for i in 0..l {
            let norm_b: f64 = (0..fast.b.cols())
                .map(|j| (fast.b.get(i, j) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            crate::testutil::assert_relclose(norm_b, fast.s[i], 1e-3, "‖b_i‖ vs s_i");
        }
    }

    #[test]
    fn rank_clamped_to_min_dim() {
        let (w, _) = slow_decay_matrix(10, 30, 10);
        let f = rsi_factorize(&w, 999, &RsiOptions::default(), &NativeEngine);
        assert_eq!(f.rank(), 10);
    }
}
