//! GEMM engine abstraction.
//!
//! The two O(C·D·ℓ) products inside Algorithm 3.1's loop — `X = W·Y` and
//! `Y = Wᵀ·X` — dominate RSI's cost. [`GemmEngine`] abstracts who executes
//! them:
//!
//! * [`NativeEngine`] — the from-scratch threaded GEMM in `linalg::gemm`.
//! * `runtime::xla_engine::XlaEngine` — the AOT Pallas/XLA artifacts via
//!   PJRT (the production path; lives next to the PJRT client).
//!
//! Keeping the trait here (not in `runtime`) lets the whole `compress`
//! module and its tests run without artifacts.

use crate::linalg::gemm;
use crate::tensor::Mat;

/// Executes the sketch-side GEMMs of Algorithm 3.1.
pub trait GemmEngine: Send + Sync {
    /// X = W · Y, with W C×D and Y D×ℓ.
    fn wy(&self, w: &Mat<f32>, y: &Mat<f32>) -> Mat<f32>;
    /// Y = Wᵀ · X, with W C×D and X C×ℓ.
    fn wtx(&self, w: &Mat<f32>, x: &Mat<f32>) -> Mat<f32>;
    /// Human-readable engine name for reports.
    fn name(&self) -> &'static str;
}

/// Engines behind `Arc` are engines too — lets `RsiFactorizer<E>` stay
/// monomorphized for the native path while accepting shared dynamic
/// engines (`Arc<dyn GemmEngine>`) from backend resources.
impl<E: GemmEngine + ?Sized> GemmEngine for std::sync::Arc<E> {
    fn wy(&self, w: &Mat<f32>, y: &Mat<f32>) -> Mat<f32> {
        (**self).wy(w, y)
    }
    fn wtx(&self, w: &Mat<f32>, x: &Mat<f32>) -> Mat<f32> {
        (**self).wtx(w, x)
    }
    fn name(&self) -> &'static str {
        (**self).name()
    }
}

/// Pure-Rust threaded GEMM engine.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeEngine;

impl GemmEngine for NativeEngine {
    fn wy(&self, w: &Mat<f32>, y: &Mat<f32>) -> Mat<f32> {
        gemm::matmul(w, y)
    }
    fn wtx(&self, w: &Mat<f32>, x: &Mat<f32>) -> Mat<f32> {
        gemm::matmul_tn(w, x)
    }
    fn name(&self) -> &'static str {
        "native"
    }
}

/// Which engine a pipeline/config selects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust GEMM (no artifacts needed).
    Native,
    /// PJRT-executed Pallas GEMM artifacts; RSI loop orchestrated in Rust.
    XlaStepped,
    /// Whole Algorithm 3.1 as one fused HLO graph (Newton–Schulz ortho).
    XlaFused,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Some(BackendKind::Native),
            "xla" | "xla-stepped" | "xla_stepped" => Some(BackendKind::XlaStepped),
            "xla-fused" | "xla_fused" | "fused" => Some(BackendKind::XlaFused),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::XlaStepped => "xla-stepped",
            BackendKind::XlaFused => "xla-fused",
        }
    }

    /// Whether this backend needs the AOT artifact registry (and therefore
    /// PJRT runtime resources) to operate.
    pub fn needs_artifacts(self) -> bool {
        !matches!(self, BackendKind::Native)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::GaussianSource;
    use crate::tensor::init::gaussian;

    #[test]
    fn native_engine_orientations() {
        let mut g = GaussianSource::new(1);
        let w = gaussian(6, 10, 1.0, &mut g);
        let y = gaussian(10, 3, 1.0, &mut g);
        let x = NativeEngine.wy(&w, &y);
        assert_eq!(x.shape(), (6, 3));
        let back = NativeEngine.wtx(&w, &x);
        assert_eq!(back.shape(), (10, 3));
        // Cross-check one entry against direct dots.
        let mut acc = 0.0f64;
        for c in 0..6 {
            acc += w.get(c, 4) as f64 * x.get(c, 1) as f64;
        }
        assert!((back.get(4, 1) as f64 - acc).abs() < 1e-3);
    }

    #[test]
    fn backend_kind_parse() {
        assert_eq!(BackendKind::parse("native"), Some(BackendKind::Native));
        assert_eq!(BackendKind::parse("XLA"), Some(BackendKind::XlaStepped));
        assert_eq!(BackendKind::parse("fused"), Some(BackendKind::XlaFused));
        assert_eq!(BackendKind::parse("tpu"), None);
        assert_eq!(BackendKind::XlaFused.name(), "xla-fused");
    }
}
