//! Compression planning: translate a sweep cell (α, q, method) into
//! per-layer jobs with exact parameter accounting — the "Ratio" column of
//! Table 4.1.

use super::rsi::RsiOptions;
use crate::io::checkpoint::{layer_infos, LayerInfo};
use crate::io::tenz::TensorFile;
use crate::util::rank_for_alpha;

/// How a layer gets factored.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    /// Randomized subspace iteration (q=1 ⇒ the RSVD baseline).
    Rsi(RsiOptions),
    /// Exact truncated SVD (the paper's optimal baseline).
    ExactSvd,
    /// A method resolved purely by its `FactorizerRegistry` key — lets
    /// external strategies plug in without touching this enum.
    Custom(&'static str),
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::Rsi(o) if o.q == 1 => "rsvd".to_string(),
            Method::Rsi(o) => format!("rsi(q={})", o.q),
            Method::ExactSvd => "svd".to_string(),
            Method::Custom(key) => key.to_string(),
        }
    }

    /// The `FactorizerRegistry` lookup key for this method.
    pub fn key(&self) -> &'static str {
        match self {
            Method::Rsi(_) => "rsi",
            Method::ExactSvd => "svd",
            Method::Custom(key) => key,
        }
    }
}

/// Per-layer job emitted by the planner.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerPlan {
    pub layer: String,
    /// Logical shape (C, D).
    pub c: usize,
    pub d: usize,
    /// Target rank k = ⌈α·min(C,D)⌉ (or explicit).
    pub k: usize,
    pub params_before: usize,
    pub params_after: usize,
}

impl LayerPlan {
    pub fn new(layer: impl Into<String>, c: usize, d: usize, k: usize) -> Self {
        LayerPlan {
            layer: layer.into(),
            c,
            d,
            k,
            params_before: c * d,
            params_after: (c + d) * k,
        }
    }
}

/// A full-model compression plan.
#[derive(Debug, Clone)]
pub struct CompressionPlan {
    pub method: Method,
    /// Uniform α applied to every linear layer (`None` ⇒ explicit ranks).
    pub alpha: Option<f64>,
    /// Explicit per-layer ranks overriding α (layer name → k).
    pub explicit_ranks: Vec<(String, usize)>,
    /// Skip layers whose min(C,D) is below this (tiny layers aren't worth
    /// the factored-storage overhead; 0 = compress everything, matching
    /// the paper which compresses all linear layers).
    pub min_dim: usize,
}

impl CompressionPlan {
    /// The paper's protocol: one α for all linear layers.
    pub fn uniform_alpha(alpha: f64, method: Method) -> Self {
        assert!(alpha > 0.0, "alpha must be positive");
        CompressionPlan { method, alpha: Some(alpha), explicit_ranks: vec![], min_dim: 0 }
    }

    /// Explicit ranks per layer (future-work §5: adaptive layer-wise ranks).
    pub fn with_ranks(ranks: Vec<(String, usize)>, method: Method) -> Self {
        CompressionPlan { method, alpha: None, explicit_ranks: ranks, min_dim: 0 }
    }

    /// Rank for a (C, D) layer under this plan; None = not covered.
    pub fn rank_for(&self, layer: &str, c: usize, d: usize) -> Option<usize> {
        if c.min(d) < self.min_dim {
            return None;
        }
        if let Some(alpha) = self.alpha {
            return Some(rank_for_alpha(alpha, c, d));
        }
        self.explicit_ranks.iter().find(|(n, _)| n == layer).map(|(_, k)| *k)
    }

    /// Expand against a checkpoint into per-layer jobs (weights with 2 dims
    /// only; biases and scalars pass through untouched).
    pub fn expand(&self, ckpt: &TensorFile) -> Vec<LayerPlan> {
        self.expand_infos(&layer_infos(ckpt))
    }

    /// Expand against pre-scanned layer metadata. The pipeline shares one
    /// [`layer_infos`] pass between planning and whole-model parameter
    /// accounting, so no tensor is ever loaded just for its shape.
    /// `params_before` is the layer's *stored* size: an already-factored
    /// input layer counts (C+D)·k, not C·D.
    pub fn expand_infos(&self, infos: &[LayerInfo]) -> Vec<LayerPlan> {
        let mut out = Vec::new();
        for info in infos {
            let (c, d) = info.shape;
            if let Some(k) = self.rank_for(&info.layer, c, d) {
                let mut p = LayerPlan::new(info.layer.clone(), c, d, k);
                p.params_before = info.stored_params;
                out.push(p);
            }
        }
        out
    }

    /// Whole-model compression ratio for a set of layer plans, given the
    /// total parameter count of the model (compressed params / original),
    /// counting uncompressed parameters unchanged — Table 4.1's "Ratio".
    pub fn model_ratio(plans: &[LayerPlan], total_params: usize) -> f64 {
        let before: usize = plans.iter().map(|p| p.params_before).sum();
        let after: usize = plans.iter().map(|p| p.params_after).sum();
        debug_assert!(before <= total_params);
        let untouched = total_params - before;
        (untouched + after) as f64 / total_params.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::checkpoint::{store_weight, StoredWeight};
    use crate::tensor::Mat;

    fn ckpt() -> TensorFile {
        let mut tf = TensorFile::new();
        store_weight(&mut tf, "layers.0", &StoredWeight::Dense(Mat::zeros(100, 400)));
        store_weight(&mut tf, "layers.1", &StoredWeight::Dense(Mat::zeros(100, 100)));
        store_weight(&mut tf, "head", &StoredWeight::Dense(Mat::zeros(10, 100)));
        tf
    }

    #[test]
    fn uniform_alpha_ranks() {
        let plan = CompressionPlan::uniform_alpha(0.4, Method::ExactSvd);
        let jobs = plan.expand(&ckpt());
        assert_eq!(jobs.len(), 3);
        assert_eq!(jobs[0].k, 40); // ceil(0.4*100)
        assert_eq!(jobs[2].k, 4); // head: ceil(0.4*10)
        assert_eq!(jobs[0].params_after, (100 + 400) * 40);
    }

    #[test]
    fn explicit_ranks_and_coverage() {
        let plan = CompressionPlan::with_ranks(
            vec![("layers.0".into(), 7), ("head".into(), 2)],
            Method::ExactSvd,
        );
        let jobs = plan.expand(&ckpt());
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs.iter().find(|j| j.layer == "head").unwrap().k, 2);
        assert!(plan.rank_for("layers.1", 100, 100).is_none());
    }

    #[test]
    fn min_dim_filter() {
        let mut plan = CompressionPlan::uniform_alpha(0.5, Method::ExactSvd);
        plan.min_dim = 50;
        let jobs = plan.expand(&ckpt());
        assert_eq!(jobs.len(), 2); // head (min dim 10) filtered out
    }

    #[test]
    fn ratio_accounting() {
        // Two layers, only one compressed: ratio mixes compressed + untouched.
        let plans = vec![LayerPlan::new("a", 100, 400, 40)];
        let total = 100 * 400 + 100 * 100;
        let r = CompressionPlan::model_ratio(&plans, total);
        let want = ((100 * 100) + (100 + 400) * 40) as f64 / total as f64;
        assert!((r - want).abs() < 1e-12);
    }

    #[test]
    fn ratio_can_exceed_one() {
        // Paper Table 4.1: α=0.8 rows show ratio 1.01–1.02 because
        // (C+D)k > C·D when k is close to min(C,D).
        let plans = vec![LayerPlan::new("a", 100, 100, 90)];
        let r = CompressionPlan::model_ratio(&plans, 100 * 100);
        assert!(r > 1.0);
    }

    #[test]
    fn method_names() {
        assert_eq!(Method::Rsi(RsiOptions::rsvd(0)).name(), "rsvd");
        assert_eq!(Method::Rsi(RsiOptions::with_q(3, 0)).name(), "rsi(q=3)");
        assert_eq!(Method::ExactSvd.name(), "svd");
        assert_eq!(Method::Custom("anchored").name(), "anchored");
    }

    #[test]
    fn method_registry_keys() {
        assert_eq!(Method::Rsi(RsiOptions::default()).key(), "rsi");
        assert_eq!(Method::ExactSvd.key(), "svd");
        assert_eq!(Method::Custom("anchored").key(), "anchored");
    }

    #[test]
    fn factored_input_layers_counted_at_stored_size() {
        let mut tf = ckpt();
        // Re-store layers.1 (100×100) as an already-factored rank-5 pair.
        store_weight(
            &mut tf,
            "layers.1",
            &StoredWeight::Factored { a: Mat::zeros(100, 5), b: Mat::zeros(5, 100) },
        );
        let plan = CompressionPlan::uniform_alpha(0.4, Method::ExactSvd);
        let jobs = plan.expand(&tf);
        let j = jobs.iter().find(|j| j.layer == "layers.1").unwrap();
        assert_eq!((j.c, j.d), (100, 100), "logical shape preserved");
        assert_eq!(j.params_before, (100 + 100) * 5, "stored, not logical, size");
        // Dense layers keep params_before = C·D.
        let j0 = jobs.iter().find(|j| j.layer == "layers.0").unwrap();
        assert_eq!(j0.params_before, 100 * 400);
    }
}
