//! Built-in model definitions (synthvgg, synthvit) and eval-set loading.
//!
//! A [`ModelDef`] binds together: the checkpoint layer naming, the forward
//! artifact's parameter feed order, and per-sample data dims — everything
//! the eval engine needs to run original or compressed weights through the
//! same compiled graph.

use crate::io::tenz::{TensorFile, TenzError};
use crate::tensor::Mat;
use anyhow::{Context, Result};

/// Supported model families.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    SynthVgg,
    SynthVit,
}

impl ModelKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "synthvgg" | "vgg" => Some(ModelKind::SynthVgg),
            "synthvit" | "vit" => Some(ModelKind::SynthVit),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ModelKind::SynthVgg => "synthvgg",
            ModelKind::SynthVit => "synthvit",
        }
    }
}

/// Static description of a model.
#[derive(Debug, Clone)]
pub struct ModelDef {
    pub kind: ModelKind,
    /// Parameter feed order of the forward artifact (after the data input);
    /// `.weight` entries are fed as (possibly reconstructed) matrices.
    pub param_order: Vec<String>,
    /// Per-sample data dims for the forward artifact ([] = flat features).
    pub sample_dims: Vec<usize>,
    /// Eval-set file name under artifacts/data/.
    pub eval_file: &'static str,
    /// Checkpoint file name under artifacts/data/.
    pub ckpt_file: &'static str,
}

const VIT_DEPTH: usize = 6;

impl ModelDef {
    pub fn get(kind: ModelKind) -> ModelDef {
        match kind {
            ModelKind::SynthVgg => ModelDef {
                kind,
                param_order: vec![
                    "layers.0.weight".into(),
                    "layers.0.bias".into(),
                    "layers.1.weight".into(),
                    "layers.1.bias".into(),
                    "head.weight".into(),
                    "head.bias".into(),
                ],
                sample_dims: vec![],
                eval_file: "eval_vgg.tenz",
                ckpt_file: "synthvgg.tenz",
            },
            ModelKind::SynthVit => {
                // Mirrors python/compile/model.py::vit_param_order().
                let mut order = vec![
                    "patch_embed.weight".to_string(),
                    "patch_embed.bias".to_string(),
                    "cls".to_string(),
                    "pos".to_string(),
                ];
                for i in 0..VIT_DEPTH {
                    let p = format!("blocks.{i}");
                    for suffix in [
                        "ln1.gamma", "ln1.beta", "wq.weight", "wk.weight", "wv.weight",
                        "wo.weight", "ln2.gamma", "ln2.beta", "fc1.weight", "fc1.bias",
                        "fc2.weight", "fc2.bias",
                    ] {
                        order.push(format!("{p}.{suffix}"));
                    }
                }
                order.extend(
                    ["ln_f.gamma", "ln_f.beta", "head.weight", "head.bias"]
                        .iter()
                        .map(|s| s.to_string()),
                );
                ModelDef {
                    kind,
                    param_order: order,
                    sample_dims: vec![16, 192],
                    eval_file: "eval_vit.tenz",
                    ckpt_file: "synthvit.tenz",
                }
            }
        }
    }

    /// Names of the compressible (2-D weight) parameters, in feed order.
    pub fn weight_names(&self) -> Vec<&str> {
        self.param_order
            .iter()
            .filter(|n| n.ends_with(".weight"))
            .map(|s| s.as_str())
            .collect()
    }

    /// Shape metadata needed to feed a non-weight parameter from a
    /// checkpoint tensor: the literal's dims. `cls`/`pos` are stored 2-D in
    /// the checkpoint but fed 3-D to the vit artifact.
    pub fn param_feed_dims(&self, name: &str, stored: &[usize]) -> Vec<usize> {
        match (self.kind, name) {
            (ModelKind::SynthVit, "cls") => vec![1, 1, stored.iter().product()],
            (ModelKind::SynthVit, "pos") => {
                vec![1, stored[0], stored[1]]
            }
            _ => stored.to_vec(),
        }
    }
}

/// A loaded evaluation set.
#[derive(Debug, Clone)]
pub struct EvalSet {
    /// One sample per row (flat features or flattened patches).
    pub data: Mat<f32>,
    pub labels: Vec<i32>,
    /// The 10 class ids present (Imagenette protocol).
    pub eval_class_ids: Vec<i32>,
    /// Feature-norm bound R of Theorem 3.2.
    pub r_bound: f32,
    /// Uncompressed reference accuracies measured at build time.
    pub top1_uncompressed: f32,
    pub top5_uncompressed: f32,
}

impl EvalSet {
    pub fn from_tenz(tf: &TensorFile, kind: ModelKind) -> Result<EvalSet> {
        let data_key = match kind {
            ModelKind::SynthVgg => "features",
            ModelKind::SynthVit => "patches",
        };
        let data = tf.mat(data_key).with_context(|| format!("eval set missing {data_key}"))?;
        let labels = tf.vec_i32("labels").context("eval set missing labels")?;
        anyhow::ensure!(data.rows() == labels.len(), "data/label count mismatch");
        let eval_class_ids = tf.vec_i32("eval_class_ids").unwrap_or_default();
        let scalar = |k: &str| -> Result<f32, TenzError> { Ok(tf.vec_f32(k)?[0]) };
        Ok(EvalSet {
            data,
            labels,
            eval_class_ids,
            r_bound: scalar("meta.R").unwrap_or(0.0),
            top1_uncompressed: scalar("meta.top1_uncompressed").unwrap_or(f32::NAN),
            top5_uncompressed: scalar("meta.top5_uncompressed").unwrap_or(f32::NAN),
        })
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::tenz::TensorEntry;

    #[test]
    fn vgg_def() {
        let def = ModelDef::get(ModelKind::SynthVgg);
        assert_eq!(def.param_order.len(), 6);
        assert_eq!(def.weight_names().len(), 3);
        assert!(def.sample_dims.is_empty());
    }

    #[test]
    fn vit_def_has_38_linear_layers() {
        // The paper stresses ViT's 37 linear layers; our synthvit has 38
        // (36 in blocks + patch embed + head).
        let def = ModelDef::get(ModelKind::SynthVit);
        assert_eq!(def.weight_names().len(), 38);
        assert_eq!(def.param_order.len(), 4 + 6 * 12 + 4);
        assert_eq!(def.sample_dims, vec![16, 192]);
    }

    #[test]
    fn vit_param_feed_dims() {
        let def = ModelDef::get(ModelKind::SynthVit);
        assert_eq!(def.param_feed_dims("cls", &[1, 192]), vec![1, 1, 192]);
        assert_eq!(def.param_feed_dims("pos", &[17, 192]), vec![1, 17, 192]);
        assert_eq!(def.param_feed_dims("ln_f.gamma", &[192]), vec![192]);
    }

    #[test]
    fn model_kind_parse() {
        assert_eq!(ModelKind::parse("VGG"), Some(ModelKind::SynthVgg));
        assert_eq!(ModelKind::parse("synthvit"), Some(ModelKind::SynthVit));
        assert_eq!(ModelKind::parse("resnet"), None);
    }

    #[test]
    fn eval_set_loading_and_validation() {
        let mut tf = TensorFile::new();
        tf.insert("features", TensorEntry::from_f32(vec![4, 8], &[0.5; 32]));
        tf.insert("labels", TensorEntry::from_i32(vec![4], &[1, 2, 3, 1]));
        tf.insert("eval_class_ids", TensorEntry::from_i32(vec![3], &[1, 2, 3]));
        tf.insert("meta.R", TensorEntry::from_f32(vec![1], &[83.0]));
        tf.insert("meta.top1_uncompressed", TensorEntry::from_f32(vec![1], &[0.8]));
        tf.insert("meta.top5_uncompressed", TensorEntry::from_f32(vec![1], &[0.95]));
        let es = EvalSet::from_tenz(&tf, ModelKind::SynthVgg).unwrap();
        assert_eq!(es.len(), 4);
        assert_eq!(es.r_bound, 83.0);
        assert_eq!(es.top1_uncompressed, 0.8);
        // Mismatched labels error.
        let mut bad = TensorFile::new();
        bad.insert("features", TensorEntry::from_f32(vec![4, 8], &[0.5; 32]));
        bad.insert("labels", TensorEntry::from_i32(vec![3], &[1, 2, 3]));
        assert!(EvalSet::from_tenz(&bad, ModelKind::SynthVgg).is_err());
    }
}
