//! Model registry: what the coordinator knows about each supported model —
//! its linear-layer inventory, how its forward artifact is fed, and where
//! its eval set lives.

pub mod registry;

pub use registry::{EvalSet, ModelDef, ModelKind};
