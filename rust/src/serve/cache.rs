//! LRU model cache: one server process, many checkpoints.
//!
//! Keys are checkpoint path + a `(length, mtime)` stat snapshot of
//! *every file backing the checkpoint* — the container itself for a
//! single `.tenz`, the manifest plus each shard for a sharded one —
//! plus the manifest's content fingerprint
//! ([`ShardManifest::identity_hash`](crate::io::shard::ShardManifest::identity_hash))
//! where one exists. mtime alone is not a staleness signal: it has
//! whole-second granularity on some filesystems, so a rewrite landing in
//! the same second as the load would serve stale weights forever. The
//! length catches same-second rewrites that change size; the identity
//! hash catches same-size rewrites of sharded checkpoints (every content
//! change flows through the per-shard hashes into the manifest).
//! Capacity-bounded with least-recently-used eviction; hit/miss/eviction
//! counters feed the [`ServeMetrics`](super::metrics::ServeMetrics)
//! table.

use super::kernel::ModelKernels;
use crate::io::checkpoint::CheckpointSource;
use crate::util::lock_recover;
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::SystemTime;

/// Identity of one loaded model: where it came from and which bytes
/// (stat snapshots + manifest fingerprint) were loaded.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ModelKey {
    pub path: PathBuf,
    /// One `(length, mtime)` per backing file: `[container]` for a
    /// single-file checkpoint, `[manifest, shard…]` (manifest order) for
    /// a sharded one. Any element changing makes a different key.
    pub stats: Vec<(u64, Option<SystemTime>)>,
    /// The manifest's content fingerprint for sharded checkpoints;
    /// `None` for single containers, which carry no stored hash.
    pub identity: Option<u64>,
}

impl ModelKey {
    /// Stat-based key snapshot for the checkpoint at `path` — the single
    /// helper both the cache probe and the sharded load path use, so a
    /// touched shard can never produce a key the probe would still match.
    pub fn snapshot(path: &Path) -> ModelKey {
        if !crate::io::shard::is_manifest_path(path) {
            return ModelKey {
                path: path.to_path_buf(),
                stats: vec![stat_of(path)],
                identity: None,
            };
        }
        let (len, mtime) = stat_of(path);
        let (identity, shard_files) = manifest_probe(path, len, mtime);
        let mut stats = vec![(len, mtime)];
        stats.extend(shard_files.iter().map(|p| stat_of(p)));
        ModelKey { path: path.to_path_buf(), stats, identity }
    }
}

/// `(length, mtime)` of `path`; `(0, None)` when it cannot be stat'ed —
/// the subsequent open reports the real error.
fn stat_of(path: &Path) -> (u64, Option<SystemTime>) {
    match std::fs::metadata(path) {
        Ok(md) => (md.len(), md.modified().ok()),
        Err(_) => (0, None),
    }
}

/// Process-wide memo of each manifest's identity hash and shard-file
/// list, keyed by the manifest's `(len, mtime)` stat. `get_or_load` runs
/// on every request, so the probe must stay at stat cost: the manifest
/// is read and parsed only when its stat changes (or the filesystem
/// reports no mtime, where staleness cannot be detected and correctness
/// wins). The memo stores only the fingerprint and file *names* — key
/// freshness still comes from live stats.
type ManifestMemo = Mutex<
    std::collections::HashMap<PathBuf, (u64, Option<SystemTime>, Option<u64>, Vec<PathBuf>)>,
>;
static MANIFESTS: std::sync::OnceLock<ManifestMemo> = std::sync::OnceLock::new();

fn manifest_probe(
    path: &Path,
    len: u64,
    mtime: Option<SystemTime>,
) -> (Option<u64>, Vec<PathBuf>) {
    let memo = MANIFESTS.get_or_init(Default::default);
    if mtime.is_some() {
        if let Some((l, t, id, files)) = lock_recover(memo).get(path) {
            if *l == len && *t == mtime {
                return (*id, files.clone());
            }
        }
    }
    let dir = path.parent().unwrap_or(Path::new("."));
    // An unreadable manifest yields no identity and no shard entries —
    // the subsequent open reports the real error.
    let (identity, files) = match crate::io::shard::ShardManifest::load(path) {
        Ok(m) => {
            let files = m.shards.iter().map(|s| dir.join(&s.file)).collect();
            (Some(m.identity_hash()), files)
        }
        Err(_) => (None, Vec::new()),
    };
    if mtime.is_some() {
        lock_recover(memo).insert(path.to_path_buf(), (len, mtime, identity, files.clone()));
    }
    (identity, files)
}

/// Thread-safe LRU cache of executable model kernels.
pub struct ModelCache {
    capacity: usize,
    /// Run the checkpoint integrity pass on every load (the `--verify`
    /// serving mode): sharded checkpoints re-hash every shard, single
    /// files take a full structural read. O(checkpoint) I/O per *miss*
    /// only — cache hits stay stat-cost.
    verify: bool,
    /// Most-recently-used first.
    inner: Mutex<VecDeque<(ModelKey, Arc<ModelKernels>)>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl ModelCache {
    pub fn new(capacity: usize) -> Self {
        Self::with_verify(capacity, false)
    }

    /// A cache that verifies checkpoint integrity at load when `verify`
    /// is set (see [`CheckpointSource::verify`]).
    pub fn with_verify(capacity: usize, verify: bool) -> Self {
        ModelCache {
            capacity: capacity.max(1),
            verify,
            inner: Mutex::new(VecDeque::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        lock_recover(&self.inner).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether `key` is currently cached (no recency update).
    pub fn contains(&self, key: &ModelKey) -> bool {
        lock_recover(&self.inner).iter().any(|(k, _)| k == key)
    }

    /// (hits, misses) counters.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Fraction of lookups served from cache (0 when never used).
    pub fn hit_rate(&self) -> f64 {
        let (h, m) = self.stats();
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Fetch (loading on miss) the kernels for the checkpoint at `path`
    /// — single `.tenz` or shard manifest alike. The lookup key pairs the
    /// path with the current `(length, mtime)` of every backing file plus
    /// the manifest fingerprint ([`ModelKey::snapshot`]), so a rewritten
    /// container *or any touched shard* misses and reloads — even when
    /// the rewrite lands inside the filesystem's mtime granularity; the
    /// stale entry ages out by LRU. Loading happens outside the lock —
    /// two threads racing on the same cold model may both load it, but
    /// the cache stays consistent (first insert wins).
    pub fn get_or_load(&self, path: &Path) -> Result<(ModelKey, Arc<ModelKernels>)> {
        let probe = ModelKey::snapshot(path);
        {
            let mut inner = lock_recover(&self.inner);
            if let Some(pos) = inner.iter().position(|(k, _)| *k == probe) {
                let entry = inner.remove(pos).expect("position just found");
                let model = entry.1.clone();
                inner.push_front(entry);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok((probe, model));
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let src = CheckpointSource::open(path)
            .with_context(|| format!("opening checkpoint {}", path.display()))?;
        if self.verify {
            src.verify()
                .with_context(|| format!("verifying checkpoint {}", path.display()))?;
        }
        // Key on the source's open-time snapshot: it describes the bytes
        // actually indexed, even if files were replaced since the stat.
        let key = ModelKey {
            path: path.to_path_buf(),
            stats: src.backing_stats(),
            identity: src.identity(),
        };
        let model = Arc::new(
            ModelKernels::load(&src)
                .with_context(|| format!("assembling kernels for {}", path.display()))?,
        );
        let mut inner = lock_recover(&self.inner);
        if let Some(pos) = inner.iter().position(|(k, _)| *k == key) {
            // Lost a load race: keep the incumbent (recency-bumped).
            let entry = inner.remove(pos).expect("position just found");
            let model = entry.1.clone();
            inner.push_front(entry);
            return Ok((key, model));
        }
        inner.push_front((key.clone(), model.clone()));
        while inner.len() > self.capacity {
            inner.pop_back();
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        Ok((key, model))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::checkpoint::{store_weight, StoredWeight};
    use crate::io::shard::ShardedWriter;
    use crate::io::tenz::TensorFile;
    use crate::rng::GaussianSource;
    use crate::tensor::init::gaussian;
    use crate::tensor::Mat;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("serve_cache_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn model_tensors(seed: u64, d: usize) -> TensorFile {
        let mut g = GaussianSource::new(seed);
        let mut tf = TensorFile::new();
        store_weight(&mut tf, "head", &StoredWeight::Dense(gaussian(3, d, 1.0, &mut g)));
        tf
    }

    fn write_model(path: &Path, seed: u64, d: usize) {
        model_tensors(seed, d).write(path).unwrap();
    }

    fn write_sharded_model(manifest: &Path, seed: u64, d: usize) {
        let tf = model_tensors(seed, d);
        let mut w = ShardedWriter::create(manifest, 256).unwrap();
        for n in tf.names().map(str::to_string).collect::<Vec<_>>() {
            w.append(&n, tf.get(&n).unwrap()).unwrap();
        }
        w.finish().unwrap();
    }

    /// Pin `path`'s mtime to `t`, so stat-visible time carries no
    /// information and staleness detection must come from length or
    /// identity.
    fn pin_mtime(path: &Path, t: SystemTime) {
        std::fs::OpenOptions::new()
            .write(true)
            .open(path)
            .unwrap()
            .set_modified(t)
            .unwrap();
    }

    #[test]
    fn hits_misses_and_lru_eviction() {
        let dir = tmp_dir("lru");
        let paths: Vec<PathBuf> = (0..3).map(|i| dir.join(format!("m{i}.tenz"))).collect();
        for (i, p) in paths.iter().enumerate() {
            write_model(p, i as u64, 4 + i);
        }
        let cache = ModelCache::new(2);
        let (k0, m0) = cache.get_or_load(&paths[0]).unwrap();
        assert_eq!(m0.input_dim(), 4);
        let _ = cache.get_or_load(&paths[1]).unwrap();
        // Hit on 0 bumps its recency.
        let (k0b, _) = cache.get_or_load(&paths[0]).unwrap();
        assert_eq!(k0, k0b);
        assert_eq!(cache.stats(), (1, 2));
        // Loading a third evicts the least-recent (1).
        let _ = cache.get_or_load(&paths[2]).unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1);
        assert!(cache.contains(&k0));
        // 1 was evicted: fetching it again is a miss.
        let _ = cache.get_or_load(&paths[1]).unwrap();
        assert_eq!(cache.stats(), (1, 4));
        assert!((cache.hit_rate() - 0.2).abs() < 1e-12);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn same_mtime_rewrite_invalidates_by_length() {
        // Regression: keys used to fold in mtime alone, so a rewrite
        // landing inside the filesystem's mtime granularity served stale
        // kernels forever. Pin the mtime to make the rewrite
        // stat-time-invisible and prove the length signal catches it.
        let dir = tmp_dir("len");
        let path = dir.join("m.tenz");
        write_model(&path, 1, 4);
        let t0 = std::fs::metadata(&path).unwrap().modified().unwrap();
        let cache = ModelCache::new(4);
        let (k1, m1) = cache.get_or_load(&path).unwrap();
        assert_eq!(m1.input_dim(), 4);
        write_model(&path, 2, 9);
        pin_mtime(&path, t0);
        let (k2, m2) = cache.get_or_load(&path).unwrap();
        assert_ne!(k1, k2, "pinned-mtime rewrite must change the key");
        assert_eq!(m2.input_dim(), 9, "new bytes must be served after rewrite");
        let (_, m3) = cache.get_or_load(&path).unwrap();
        assert_eq!(m3.input_dim(), 9);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn same_mtime_sharded_rewrite_invalidates_by_identity() {
        // Same-shape, different-content rewrite of a sharded checkpoint:
        // every shard keeps its byte size, and every mtime is pinned back
        // to the original, so only the manifest identity hash (fed by the
        // per-shard content hashes) can tell the two checkpoints apart.
        let dir = tmp_dir("identity");
        let manifest = dir.join("m.toml");
        write_sharded_model(&manifest, 1, 6);
        let mut pinned: Vec<(PathBuf, SystemTime)> = Vec::new();
        for e in std::fs::read_dir(&dir).unwrap() {
            let p = e.unwrap().path();
            pinned.push((p.clone(), std::fs::metadata(&p).unwrap().modified().unwrap()));
        }
        let cache = ModelCache::new(4);
        let (k1, m1) = cache.get_or_load(&manifest).unwrap();
        assert_eq!(m1.input_dim(), 6);
        let ones = Mat::from_fn(1, 6, |_, _| 1.0);
        let v1 = m1.forward(&ones);

        write_sharded_model(&manifest, 2, 6);
        for (p, t) in &pinned {
            pin_mtime(p, *t);
        }
        let (k2, m2) = cache.get_or_load(&manifest).unwrap();
        assert_ne!(
            k1.identity, k2.identity,
            "different shard content must change the manifest fingerprint"
        );
        assert_ne!(k1, k2, "pinned-mtime sharded rewrite must change the key");
        let v2 = m2.forward(&ones);
        assert_ne!(v1.data(), v2.data(), "new weights must be served after rewrite");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn poisoned_cache_lock_recovers() {
        // A panic on one request thread while holding the cache lock must
        // not wedge every later request with a PoisonError.
        let dir = tmp_dir("poison");
        let path = dir.join("m.tenz");
        write_model(&path, 3, 5);
        let cache = Arc::new(ModelCache::new(2));
        let (k1, _) = cache.get_or_load(&path).unwrap();
        let c2 = Arc::clone(&cache);
        let _ = std::thread::spawn(move || {
            let _g = c2.inner.lock().unwrap();
            panic!("injected panic while holding the cache lock");
        })
        .join();
        assert!(cache.inner.lock().is_err(), "lock should be poisoned");
        let (k2, m) = cache.get_or_load(&path).unwrap();
        assert_eq!(k1, k2, "cached entry must survive the poisoned lock");
        assert_eq!(m.input_dim(), 5);
        assert_eq!(cache.stats().0, 1, "post-poison lookup is a plain hit");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_file_is_error_not_poison() {
        let cache = ModelCache::new(2);
        assert!(cache.get_or_load(Path::new("/nonexistent/m.tenz")).is_err());
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.stats(), (0, 1));
    }
}
