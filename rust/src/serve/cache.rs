//! LRU model cache: one server process, many checkpoints.
//!
//! Keys are checkpoint path + modification-time snapshot of *every file
//! backing the checkpoint* — the container itself for a single `.tenz`,
//! the manifest plus each shard for a sharded checkpoint — so rewriting
//! any of them on disk (a new compression run finishing, one shard
//! re-rolled, say) invalidates the cached kernels instead of serving
//! stale weights. Capacity-bounded with least-recently-used eviction;
//! hit/miss/eviction counters feed the
//! [`ServeMetrics`](super::metrics::ServeMetrics) table.

use super::kernel::ModelKernels;
use crate::io::checkpoint::CheckpointSource;
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::SystemTime;

/// Identity of one loaded model: where it came from and which bytes
/// (mtime snapshots) were loaded.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ModelKey {
    pub path: PathBuf,
    /// One snapshot per backing file: `[container]` for a single-file
    /// checkpoint, `[manifest, shard…]` (manifest order) for a sharded
    /// one. Any element changing makes a different key.
    pub mtimes: Vec<Option<SystemTime>>,
}

impl ModelKey {
    /// Stat-based key snapshot for the checkpoint at `path` — the single
    /// helper both the cache probe and the sharded load path use, so a
    /// touched shard can never produce a key the probe would still match.
    pub fn snapshot(path: &Path) -> ModelKey {
        ModelKey { path: path.to_path_buf(), mtimes: snapshot_mtimes(path) }
    }
}

fn mtime_of(path: &Path) -> Option<SystemTime> {
    std::fs::metadata(path).and_then(|m| m.modified()).ok()
}

/// Process-wide memo of each manifest's shard-file list, keyed by the
/// manifest's `(len, mtime)` stat. `get_or_load` runs on every request,
/// so the probe must stay at stat cost: the manifest is read and parsed
/// only when its stat changes (or the filesystem reports no mtime, where
/// staleness cannot be detected and correctness wins). The memo stores
/// only file *names* — key freshness still comes from live stats.
type ShardListMemo =
    Mutex<std::collections::HashMap<PathBuf, (u64, Option<SystemTime>, Vec<PathBuf>)>>;
static SHARD_LISTS: std::sync::OnceLock<ShardListMemo> = std::sync::OnceLock::new();

fn shard_paths_of(path: &Path, len: u64, mtime: Option<SystemTime>) -> Vec<PathBuf> {
    let memo = SHARD_LISTS.get_or_init(Default::default);
    if mtime.is_some() {
        if let Some((l, t, files)) = memo.lock().unwrap().get(path) {
            if *l == len && *t == mtime {
                return files.clone();
            }
        }
    }
    let dir = path.parent().unwrap_or(Path::new("."));
    // An unreadable manifest yields no shard entries — the subsequent
    // open reports the real error.
    let files: Vec<PathBuf> = crate::io::shard::ShardManifest::load(path)
        .map(|m| m.shards.iter().map(|s| dir.join(&s.file)).collect())
        .unwrap_or_default();
    if mtime.is_some() {
        memo.lock().unwrap().insert(path.to_path_buf(), (len, mtime, files.clone()));
    }
    files
}

/// Modification times of every file backing the checkpoint at `path`,
/// by `stat` alone on the warm path: `[container]` for a `.tenz`,
/// `[manifest, shard…]` for a manifest (shard list memoized against the
/// manifest's stat, so cache hits never re-parse it).
fn snapshot_mtimes(path: &Path) -> Vec<Option<SystemTime>> {
    if !crate::io::shard::is_manifest_path(path) {
        return vec![mtime_of(path)];
    }
    let (len, mtime) = match std::fs::metadata(path) {
        Ok(md) => (md.len(), md.modified().ok()),
        Err(_) => (0, None),
    };
    let mut v = vec![mtime];
    v.extend(shard_paths_of(path, len, mtime).iter().map(|p| mtime_of(p)));
    v
}

/// Thread-safe LRU cache of executable model kernels.
pub struct ModelCache {
    capacity: usize,
    /// Run the checkpoint integrity pass on every load (the `--verify`
    /// serving mode): sharded checkpoints re-hash every shard, single
    /// files take a full structural read. O(checkpoint) I/O per *miss*
    /// only — cache hits stay stat-cost.
    verify: bool,
    /// Most-recently-used first.
    inner: Mutex<VecDeque<(ModelKey, Arc<ModelKernels>)>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl ModelCache {
    pub fn new(capacity: usize) -> Self {
        Self::with_verify(capacity, false)
    }

    /// A cache that verifies checkpoint integrity at load when `verify`
    /// is set (see [`CheckpointSource::verify`]).
    pub fn with_verify(capacity: usize, verify: bool) -> Self {
        ModelCache {
            capacity: capacity.max(1),
            verify,
            inner: Mutex::new(VecDeque::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether `key` is currently cached (no recency update).
    pub fn contains(&self, key: &ModelKey) -> bool {
        self.inner.lock().unwrap().iter().any(|(k, _)| k == key)
    }

    /// (hits, misses) counters.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Fraction of lookups served from cache (0 when never used).
    pub fn hit_rate(&self) -> f64 {
        let (h, m) = self.stats();
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Fetch (loading on miss) the kernels for the checkpoint at `path`
    /// — single `.tenz` or shard manifest alike. The lookup key pairs the
    /// path with the current mtimes of every backing file
    /// ([`ModelKey::snapshot`]), so a rewritten container *or any touched
    /// shard* misses and reloads; the stale entry ages out by LRU.
    /// Loading happens outside the lock — two threads racing on the same
    /// cold model may both load it, but the cache stays consistent
    /// (first insert wins).
    pub fn get_or_load(&self, path: &Path) -> Result<(ModelKey, Arc<ModelKernels>)> {
        let probe = ModelKey::snapshot(path);
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(pos) = inner.iter().position(|(k, _)| *k == probe) {
                let entry = inner.remove(pos).expect("position just found");
                let model = entry.1.clone();
                inner.push_front(entry);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok((probe, model));
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let src = CheckpointSource::open(path)
            .with_context(|| format!("opening checkpoint {}", path.display()))?;
        if self.verify {
            src.verify()
                .with_context(|| format!("verifying checkpoint {}", path.display()))?;
        }
        // Key on the source's open-time snapshot: it describes the bytes
        // actually indexed, even if files were replaced since the stat.
        // Fall back to the probe where the filesystem reported nothing.
        let snap = src.modified_snapshot();
        let mtimes =
            if snap.iter().all(Option::is_none) { probe.mtimes.clone() } else { snap };
        let key = ModelKey { path: path.to_path_buf(), mtimes };
        let model = Arc::new(
            ModelKernels::load(&src)
                .with_context(|| format!("assembling kernels for {}", path.display()))?,
        );
        let mut inner = self.inner.lock().unwrap();
        if let Some(pos) = inner.iter().position(|(k, _)| *k == key) {
            // Lost a load race: keep the incumbent (recency-bumped).
            let entry = inner.remove(pos).expect("position just found");
            let model = entry.1.clone();
            inner.push_front(entry);
            return Ok((key, model));
        }
        inner.push_front((key.clone(), model.clone()));
        while inner.len() > self.capacity {
            inner.pop_back();
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        Ok((key, model))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::checkpoint::{store_weight, StoredWeight};
    use crate::io::tenz::TensorFile;
    use crate::rng::GaussianSource;
    use crate::tensor::init::gaussian;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("serve_cache_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn write_model(path: &Path, seed: u64, d: usize) {
        let mut g = GaussianSource::new(seed);
        let mut tf = TensorFile::new();
        store_weight(&mut tf, "head", &StoredWeight::Dense(gaussian(3, d, 1.0, &mut g)));
        tf.write(path).unwrap();
    }

    #[test]
    fn hits_misses_and_lru_eviction() {
        let dir = tmp_dir("lru");
        let paths: Vec<PathBuf> = (0..3).map(|i| dir.join(format!("m{i}.tenz"))).collect();
        for (i, p) in paths.iter().enumerate() {
            write_model(p, i as u64, 4 + i);
        }
        let cache = ModelCache::new(2);
        let (k0, m0) = cache.get_or_load(&paths[0]).unwrap();
        assert_eq!(m0.input_dim(), 4);
        let _ = cache.get_or_load(&paths[1]).unwrap();
        // Hit on 0 bumps its recency.
        let (k0b, _) = cache.get_or_load(&paths[0]).unwrap();
        assert_eq!(k0, k0b);
        assert_eq!(cache.stats(), (1, 2));
        // Loading a third evicts the least-recent (1).
        let _ = cache.get_or_load(&paths[2]).unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1);
        assert!(cache.contains(&k0));
        // 1 was evicted: fetching it again is a miss.
        let _ = cache.get_or_load(&paths[1]).unwrap();
        assert_eq!(cache.stats(), (1, 4));
        assert!((cache.hit_rate() - 0.2).abs() < 1e-12);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rewritten_checkpoint_invalidates() {
        let dir = tmp_dir("mtime");
        let path = dir.join("m.tenz");
        write_model(&path, 1, 4);
        let cache = ModelCache::new(4);
        let (k1, m1) = cache.get_or_load(&path).unwrap();
        assert_eq!(m1.input_dim(), 4);
        // Rewrite with a different shape and a bumped mtime (filesystem
        // mtime granularity can be coarse — set it explicitly via a
        // sleep-free monotone touch: rewriting content is enough when the
        // clock ticks, so nudge it with a short sleep only if needed).
        std::thread::sleep(std::time::Duration::from_millis(20));
        write_model(&path, 2, 9);
        let (k2, m2) = cache.get_or_load(&path).unwrap();
        if k2 == k1 {
            // mtime granularity too coarse to distinguish — nothing to
            // assert beyond the cache staying consistent.
            assert_eq!(m2.input_dim(), 4);
        } else {
            assert_eq!(m2.input_dim(), 9, "new bytes must be served after rewrite");
            let (_, m3) = cache.get_or_load(&path).unwrap();
            assert_eq!(m3.input_dim(), 9);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_file_is_error_not_poison() {
        let cache = ModelCache::new(2);
        assert!(cache.get_or_load(Path::new("/nonexistent/m.tenz")).is_err());
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.stats(), (0, 1));
    }
}
