//! Deterministic open-loop arrival processes and the hot-key sampler.
//!
//! An open-loop generator decides *when* requests arrive from a seeded
//! stochastic process, never from response latency — so overload looks
//! like production overload (arrivals keep coming while the server
//! drowns) instead of the closed-loop self-throttling of
//! [`traffic::drive`](crate::serve::traffic::drive). Every process here
//! is a **pure function of `(process, seed, duration)`**: the schedule
//! is computed up front from a private [`Pcg64`] stream, so two runs
//! with the same seed produce bit-identical arrival times no matter how
//! threads are scheduled — the property `tests/traffic_scenarios.rs`
//! locks down.

use crate::rng::Pcg64;

/// Stream selector keeping arrival draws out of every other consumer of
/// the same seed ("ARRV").
const ARRIVAL_STREAM: u64 = 0x4152_5256;

/// Exponential inter-arrival gap at `rate` events/sec (inverse CDF over
/// an open-interval uniform, so `ln` never sees 0).
fn exp_gap(rng: &mut Pcg64, rate: f64) -> f64 {
    -rng.next_f64_open().ln() / rate
}

/// A seeded arrival process generating request times on `[0, duration)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at a constant `rate` (events/sec).
    Poisson { rate: f64 },
    /// On/off Markov-modulated Poisson: bursts of Poisson arrivals at
    /// `rate` lasting `mean_on` seconds on average, separated by silent
    /// gaps of `mean_off` seconds on average (both exponentially
    /// distributed). Long-run mean rate = `rate·mean_on/(mean_on+mean_off)`.
    Bursty { rate: f64, mean_on: f64, mean_off: f64 },
    /// Sinusoid-modulated rate `base·(1 + amplitude·sin(2πt/period))`
    /// realized by thinning a Poisson stream at the peak rate — the
    /// compressed-timescale stand-in for a diurnal load curve.
    Diurnal { base: f64, amplitude: f64, period: f64 },
}

impl ArrivalProcess {
    /// Long-run mean arrival rate (events/sec) — what capacity planning
    /// compares against server throughput.
    pub fn mean_rate(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate } => rate,
            ArrivalProcess::Bursty { rate, mean_on, mean_off } => {
                if mean_on + mean_off <= 0.0 {
                    0.0
                } else {
                    rate * mean_on / (mean_on + mean_off)
                }
            }
            ArrivalProcess::Diurnal { base, .. } => base,
        }
    }

    /// The same process with every rate multiplied by `factor` — the
    /// overload knob the degradation-curve sweep turns.
    pub fn scaled(&self, factor: f64) -> ArrivalProcess {
        match *self {
            ArrivalProcess::Poisson { rate } => ArrivalProcess::Poisson { rate: rate * factor },
            ArrivalProcess::Bursty { rate, mean_on, mean_off } => {
                ArrivalProcess::Bursty { rate: rate * factor, mean_on, mean_off }
            }
            ArrivalProcess::Diurnal { base, amplitude, period } => {
                ArrivalProcess::Diurnal { base: base * factor, amplitude, period }
            }
        }
    }

    /// Generate the full arrival schedule on `[0, duration)`: strictly
    /// increasing times, a pure function of `(self, seed, duration)`.
    pub fn schedule(&self, seed: u64, duration: f64) -> Vec<f64> {
        let mut rng = Pcg64::with_stream(seed, ARRIVAL_STREAM);
        let mut out = Vec::new();
        match *self {
            ArrivalProcess::Poisson { rate } => {
                if rate <= 0.0 || duration <= 0.0 {
                    return out;
                }
                let mut t = exp_gap(&mut rng, rate);
                while t < duration {
                    out.push(t);
                    t += exp_gap(&mut rng, rate);
                }
            }
            ArrivalProcess::Bursty { rate, mean_on, mean_off } => {
                if rate <= 0.0 || duration <= 0.0 || mean_on <= 0.0 || mean_off < 0.0 {
                    return out;
                }
                let mut t = 0.0;
                let mut on = true; // runs open mid-burst: traffic exists at t=0
                while t < duration {
                    let phase = if on {
                        exp_gap(&mut rng, 1.0 / mean_on)
                    } else {
                        exp_gap(&mut rng, 1.0 / mean_off.max(1e-12))
                    };
                    let end = (t + phase).min(duration);
                    if on {
                        let mut a = t + exp_gap(&mut rng, rate);
                        while a < end {
                            out.push(a);
                            a += exp_gap(&mut rng, rate);
                        }
                    }
                    t += phase;
                    on = !on;
                }
            }
            ArrivalProcess::Diurnal { base, amplitude, period } => {
                if base <= 0.0 || duration <= 0.0 || period <= 0.0 {
                    return out;
                }
                let amp = amplitude.clamp(0.0, 1.0);
                let peak = base * (1.0 + amp);
                let mut t = exp_gap(&mut rng, peak);
                while t < duration {
                    let rate_t =
                        base * (1.0 + amp * (std::f64::consts::TAU * t / period).sin());
                    // Poisson thinning: keep with probability rate(t)/peak.
                    if rng.next_f64() * peak < rate_t {
                        out.push(t);
                    }
                    t += exp_gap(&mut rng, peak);
                }
            }
        }
        out
    }
}

/// Zipf(s) sampler over `n` ranked items — the hot-key skew of real
/// multi-model traffic (a few checkpoints take most of the hits).
/// `s = 0` degenerates to uniform. Sampling is inverse-CDF over the
/// normalized weights `1/(i+1)^s`, so it is as deterministic as the rng
/// stream feeding it.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Zipf {
        assert!(n >= 1, "Zipf over an empty set");
        let s = s.max(0.0);
        let mut cdf: Vec<f64> = (0..n).map(|i| ((i + 1) as f64).powf(-s)).collect();
        let total: f64 = cdf.iter().sum();
        let mut acc = 0.0;
        for w in &mut cdf {
            acc += *w / total;
            *w = acc;
        }
        if let Some(last) = cdf.last_mut() {
            *last = 1.0; // guard the running sum against fp drift
        }
        Zipf { cdf }
    }

    /// Draw one index in `0..n` (0 is the hottest key).
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        self.cdf.partition_point(|&c| c <= u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules_are_pure_functions_of_seed_rate_duration() {
        for process in [
            ArrivalProcess::Poisson { rate: 800.0 },
            ArrivalProcess::Bursty { rate: 2000.0, mean_on: 0.05, mean_off: 0.05 },
            ArrivalProcess::Diurnal { base: 800.0, amplitude: 0.8, period: 1.0 },
        ] {
            let a = process.schedule(42, 2.0);
            let b = process.schedule(42, 2.0);
            assert_eq!(a, b, "{process:?} not deterministic");
            assert!(!a.is_empty());
            assert!(a.windows(2).all(|w| w[0] <= w[1]), "{process:?} times not sorted");
            assert!(a.iter().all(|&t| (0.0..2.0).contains(&t)));
            let c = process.schedule(43, 2.0);
            assert_ne!(a, c, "{process:?} ignores the seed");
        }
    }

    /// The Poisson schedule is exactly the textbook construction
    /// t += -ln(U)/rate over this rng stream — an executable golden
    /// reference (stronger than frozen constants: it pins the formula
    /// *and* the stream, for every prefix, not just the first 20).
    #[test]
    fn poisson_schedule_matches_the_inverse_cdf_formula() {
        let rate = 500.0;
        let got = ArrivalProcess::Poisson { rate }.schedule(7, 1.0);
        let mut rng = Pcg64::with_stream(7, ARRIVAL_STREAM);
        let mut expect = Vec::new();
        let mut t = -rng.next_f64_open().ln() / rate;
        while t < 1.0 {
            expect.push(t);
            t += -rng.next_f64_open().ln() / rate;
        }
        assert_eq!(got.len(), expect.len());
        for (g, e) in got.iter().zip(&expect).take(20) {
            assert_eq!(g.to_bits(), e.to_bits(), "schedule diverges from the formula");
        }
        assert_eq!(got, expect);
    }

    #[test]
    fn poisson_count_tracks_rate_times_duration() {
        let n = ArrivalProcess::Poisson { rate: 1000.0 }.schedule(1, 4.0).len() as f64;
        // 4000 expected, sd ≈ 63 — 5 sd of slack.
        assert!((n - 4000.0).abs() < 320.0, "got {n} arrivals for E=4000");
    }

    #[test]
    fn bursty_long_run_rate_honors_the_duty_cycle() {
        let p = ArrivalProcess::Bursty { rate: 2000.0, mean_on: 0.05, mean_off: 0.15 };
        assert!((p.mean_rate() - 500.0).abs() < 1e-9);
        let n = p.schedule(3, 8.0).len() as f64;
        // E = 4000 over 8 s; burst structure fattens the variance a lot.
        assert!((n - 4000.0).abs() < 1200.0, "got {n} arrivals for E=4000");
    }

    #[test]
    fn diurnal_peaks_where_the_sinusoid_peaks() {
        let p = ArrivalProcess::Diurnal { base: 2000.0, amplitude: 0.9, period: 1.0 };
        let times = p.schedule(11, 1.0);
        // sin peaks at t=0.25, troughs at t=0.75 within one period.
        let peak = times.iter().filter(|&&t| (0.15..0.35).contains(&t)).count();
        let trough = times.iter().filter(|&&t| (0.65..0.85).contains(&t)).count();
        assert!(
            peak as f64 > 3.0 * trough.max(1) as f64,
            "peak window {peak} vs trough window {trough}"
        );
    }

    #[test]
    fn scaled_multiplies_the_mean_rate() {
        for p in [
            ArrivalProcess::Poisson { rate: 100.0 },
            ArrivalProcess::Bursty { rate: 100.0, mean_on: 0.1, mean_off: 0.1 },
            ArrivalProcess::Diurnal { base: 100.0, amplitude: 0.5, period: 2.0 },
        ] {
            assert!((p.scaled(3.0).mean_rate() - 3.0 * p.mean_rate()).abs() < 1e-9);
        }
    }

    #[test]
    fn zipf_skews_toward_the_head() {
        let z = Zipf::new(8, 1.2);
        let mut rng = Pcg64::new(9);
        let mut counts = [0usize; 8];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[1] && counts[1] > counts[3]);
        assert!(counts[0] > 4 * counts[7], "head {} tail {}", counts[0], counts[7]);
        // s = 0 is uniform: every index within 20% of the mean.
        let u = Zipf::new(4, 0.0);
        let mut counts = [0usize; 4];
        for _ in 0..20_000 {
            counts[u.sample(&mut rng)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 5000.0).abs() < 1000.0, "{counts:?}");
        }
    }
}
