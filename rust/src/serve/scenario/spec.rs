//! The TOML scenario spec: a declarative description of a multi-tenant
//! traffic mix, parsed by the crate's own `config::toml` subset parser.
//!
//! ```toml
//! name = "evening-rush"
//! seed = 42
//! duration = 2.0          # seconds of schedule
//! load_factor = 1.0       # global rate multiplier (the overload knob)
//!
//! [tenant.gold]
//! models = ["a.tenz", "b.tenz"]
//! arrivals = "poisson"    # "poisson" | "bursty" | "diurnal"
//! rate = 800.0            # events/sec (bursty: in-burst; diurnal: base)
//! zipf = 1.1              # hot-key skew over `models` (0 = uniform)
//! weight = 3              # deficit-round-robin drain weight
//! quota = 256             # per-tenant queue bound
//! deadline_ms = 50.0      # queue deadline == the p99 SLO target
//! degrade_to = "a_r8.tenz" # overflow reroutes here instead of shedding
//!
//! [tenant.free]
//! models = ["a.tenz"]
//! arrivals = "bursty"
//! rate = 4000.0
//! mean_on = 0.05
//! mean_off = 0.10
//! ```
//!
//! Only `models` and `rate` are required per tenant; everything else has
//! the defaults documented on [`TenantSpec`].

use super::arrivals::ArrivalProcess;
use crate::config::toml::{TomlDoc, TomlValue};
use crate::serve::batcher::TenantPolicy;
use anyhow::{bail, Context, Result};
use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

/// One tenant's slice of the scenario: which checkpoints it hits, how
/// its arrivals are shaped, and the admission policy it runs under.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: String,
    /// Checkpoints this tenant draws from (Zipf rank order: first =
    /// hottest).
    pub models: Vec<PathBuf>,
    /// Zipf exponent for the hot-key skew over `models` (0 = uniform).
    pub zipf: f64,
    pub process: ArrivalProcess,
    /// Deficit-round-robin drain weight (default 1).
    pub weight: u32,
    /// Per-tenant queue bound (default: server-wide default).
    pub quota: Option<usize>,
    /// Queue deadline in ms — doubles as the p99 SLO target.
    pub deadline_ms: Option<f64>,
    /// Sibling checkpoint overflow reroutes to instead of shedding.
    pub degrade_to: Option<PathBuf>,
}

/// A parsed traffic scenario.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: String,
    pub seed: u64,
    /// Seconds of arrival schedule per tenant.
    pub duration: f64,
    /// Global rate multiplier applied on top of every tenant's process.
    pub load_factor: f64,
    pub tenants: Vec<TenantSpec>,
}

fn opt_f64(doc: &TomlDoc, key: &str) -> Option<f64> {
    doc.get(key).and_then(TomlValue::as_float)
}

fn opt_int(doc: &TomlDoc, key: &str) -> Option<i64> {
    doc.get(key).and_then(TomlValue::as_int)
}

fn opt_str<'a>(doc: &'a TomlDoc, key: &str) -> Option<&'a str> {
    doc.get(key).and_then(TomlValue::as_str)
}

impl ScenarioSpec {
    pub fn parse(text: &str) -> Result<ScenarioSpec> {
        let doc = TomlDoc::parse(text).context("parsing scenario TOML")?;
        Self::from_doc(&doc)
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<ScenarioSpec> {
        let path = path.as_ref();
        let doc = TomlDoc::load(path)
            .with_context(|| format!("loading scenario {}", path.display()))?;
        Self::from_doc(&doc)
            .with_context(|| format!("in scenario {}", path.display()))
    }

    fn from_doc(doc: &TomlDoc) -> Result<ScenarioSpec> {
        let name = opt_str(doc, "name").unwrap_or("scenario").to_string();
        let seed = opt_int(doc, "seed").unwrap_or(42) as u64;
        let duration = opt_f64(doc, "duration").unwrap_or(1.0);
        let load_factor = opt_f64(doc, "load_factor").unwrap_or(1.0);
        if duration <= 0.0 || load_factor <= 0.0 {
            bail!("duration and load_factor must be positive");
        }
        // keys_under("tenant") yields "gold.rate", "gold.models", … —
        // the first segment is the tenant name (BTreeSet: stable order).
        let mut names = BTreeSet::new();
        for key in doc.keys_under("tenant") {
            if let Some(tenant) = key.split('.').next() {
                if !tenant.is_empty() {
                    names.insert(tenant.to_string());
                }
            }
        }
        if names.is_empty() {
            bail!("scenario declares no [tenant.*] tables");
        }
        let mut tenants = Vec::with_capacity(names.len());
        for tenant in names {
            let key = |suffix: &str| format!("tenant.{tenant}.{suffix}");
            let models_val = doc
                .get(&key("models"))
                .with_context(|| format!("tenant {tenant}: missing `models`"))?;
            let models: Vec<PathBuf> = models_val
                .as_array()
                .map(|items| {
                    items.iter().filter_map(TomlValue::as_str).map(PathBuf::from).collect()
                })
                .or_else(|| models_val.as_str().map(|s| vec![PathBuf::from(s)]))
                .unwrap_or_default();
            if models.is_empty() {
                bail!("tenant {tenant}: `models` must name at least one checkpoint");
            }
            let rate = opt_f64(doc, &key("rate"))
                .with_context(|| format!("tenant {tenant}: missing `rate`"))?;
            if rate <= 0.0 {
                bail!("tenant {tenant}: rate must be positive");
            }
            let kind = opt_str(doc, &key("arrivals")).unwrap_or("poisson");
            let process = match kind {
                "poisson" => ArrivalProcess::Poisson { rate },
                "bursty" => {
                    let mean_on = opt_f64(doc, &key("mean_on")).unwrap_or(0.05);
                    let mean_off = opt_f64(doc, &key("mean_off")).unwrap_or(mean_on);
                    ArrivalProcess::Bursty { rate, mean_on, mean_off }
                }
                "diurnal" => ArrivalProcess::Diurnal {
                    base: rate,
                    amplitude: opt_f64(doc, &key("amplitude")).unwrap_or(0.8),
                    period: opt_f64(doc, &key("period")).unwrap_or(duration),
                },
                other => bail!(
                    "tenant {tenant}: unknown arrivals kind {other:?} \
                     (expected poisson|bursty|diurnal)"
                ),
            };
            let weight = opt_int(doc, &key("weight")).unwrap_or(1).max(1) as u32;
            let quota = opt_int(doc, &key("quota")).map(|q| q.max(0) as usize);
            let deadline_ms = opt_f64(doc, &key("deadline_ms"));
            let degrade_to = opt_str(doc, &key("degrade_to")).map(PathBuf::from);
            tenants.push(TenantSpec {
                name: tenant,
                models,
                zipf: opt_f64(doc, &key("zipf")).unwrap_or(0.0),
                process,
                weight,
                quota,
                deadline_ms,
                degrade_to,
            });
        }
        Ok(ScenarioSpec { name, seed, duration, load_factor, tenants })
    }

    /// The spec with `load_factor` multiplied by `factor` — the knob a
    /// degradation-curve sweep turns between runs.
    pub fn scaled(&self, factor: f64) -> ScenarioSpec {
        let mut spec = self.clone();
        spec.load_factor *= factor;
        spec
    }

    /// Every checkpoint the scenario can touch (tenant models + degrade
    /// siblings), deduplicated, in stable order — the warm-load set.
    pub fn all_paths(&self) -> Vec<PathBuf> {
        let mut seen = BTreeSet::new();
        let mut out = Vec::new();
        for t in &self.tenants {
            for p in t.models.iter().chain(t.degrade_to.as_ref()) {
                if seen.insert(p.clone()) {
                    out.push(p.clone());
                }
            }
        }
        out
    }

    /// Server-side admission policies for [`ServeConfig::tenants`]
    /// (crate::serve::ServeConfig) matching this scenario's tenants.
    pub fn tenant_policies(&self) -> Vec<TenantPolicy> {
        self.tenants
            .iter()
            .map(|t| TenantPolicy {
                name: Arc::from(t.name.as_str()),
                weight: t.weight,
                queue_quota: t.quota,
                deadline: t.deadline_ms.map(|ms| Duration::from_secs_f64(ms / 1e3)),
                degrade_to: t.degrade_to.clone(),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &str = r#"
name = "rush"
seed = 7
duration = 2.0

[tenant.gold]
models = ["a.tenz", "b.tenz"]
arrivals = "poisson"
rate = 500.0
zipf = 1.1
weight = 3
quota = 128
deadline_ms = 40.0
degrade_to = "a_r8.tenz"

[tenant.free]
models = "a.tenz"
arrivals = "bursty"
rate = 2000.0
mean_on = 0.05
mean_off = 0.1
"#;

    #[test]
    fn parses_the_full_schema() {
        let spec = ScenarioSpec::parse(SPEC).unwrap();
        assert_eq!(spec.name, "rush");
        assert_eq!(spec.seed, 7);
        assert_eq!(spec.tenants.len(), 2);
        let free = &spec.tenants[0]; // BTreeSet order: free < gold
        assert_eq!(free.name, "free");
        assert_eq!(free.models, vec![PathBuf::from("a.tenz")]);
        assert!(matches!(free.process, ArrivalProcess::Bursty { rate, .. } if rate == 2000.0));
        let gold = &spec.tenants[1];
        assert_eq!(gold.weight, 3);
        assert_eq!(gold.quota, Some(128));
        assert_eq!(gold.deadline_ms, Some(40.0));
        assert_eq!(gold.degrade_to, Some(PathBuf::from("a_r8.tenz")));
        // all_paths: models + degrade siblings, deduped.
        let paths = spec.all_paths();
        assert_eq!(paths.len(), 3, "{paths:?}");
        let policies = spec.tenant_policies();
        let gold_pol = policies.iter().find(|p| &*p.name == "gold").unwrap();
        assert_eq!(gold_pol.queue_quota, Some(128));
        assert_eq!(gold_pol.deadline, Some(Duration::from_millis(40)));
    }

    #[test]
    fn scaled_turns_only_the_load_factor() {
        let spec = ScenarioSpec::parse(SPEC).unwrap();
        let hot = spec.scaled(10.0);
        assert!((hot.load_factor - 10.0).abs() < 1e-12);
        assert_eq!(hot.tenants.len(), spec.tenants.len());
    }

    #[test]
    fn rejects_broken_specs() {
        assert!(ScenarioSpec::parse("name = \"empty\"").is_err(), "no tenants");
        let no_rate = "[tenant.t]\nmodels = [\"m.tenz\"]\n";
        assert!(ScenarioSpec::parse(no_rate).is_err(), "missing rate");
        let bad_kind = "[tenant.t]\nmodels = [\"m.tenz\"]\nrate = 1.0\narrivals = \"square\"\n";
        assert!(ScenarioSpec::parse(bad_kind).is_err(), "unknown arrivals kind");
        let neg = "duration = -1.0\n[tenant.t]\nmodels = [\"m.tenz\"]\nrate = 1.0\n";
        assert!(ScenarioSpec::parse(neg).is_err(), "negative duration");
    }
}
