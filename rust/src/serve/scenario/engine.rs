//! The scenario engine: plan a deterministic open-loop schedule, drive
//! it against a [`Server`], and report per-tenant outcomes.
//!
//! Planning and driving are deliberately split. [`plan`] turns a
//! [`ScenarioSpec`] into a flat, time-sorted list of
//! [`PlannedArrival`]s — every arrival time, tenant, model pick, and
//! per-request seed fixed *before any thread runs*, so the request
//! multiset is a pure function of the spec. [`run_scenario`] then paces
//! that schedule against the wall clock from a handful of submitter
//! threads (open loop: a slow server changes nothing about when the
//! next request is submitted) while a collector thread polls responses,
//! so client-side waiting never blocks the arrival stream.

use super::arrivals::Zipf;
use super::spec::ScenarioSpec;
use crate::bench::stats::percentile;
use crate::io::tenz::Fnv1a;
use crate::report::Table;
use crate::rng::{derive_seed, GaussianSource, Pcg64};
use crate::serve::batcher::RequestError;
use crate::serve::server::{Admission, Server};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Stream selector for the per-tenant model-pick rng ("ZIPF").
const MODEL_PICK_STREAM: u64 = 0x5a49_5046;

/// One scheduled request, fully determined at plan time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlannedArrival {
    /// Seconds after scenario start.
    pub at: f64,
    /// Index into [`ScenarioSpec::tenants`].
    pub tenant: usize,
    /// Index into that tenant's `models` (Zipf-sampled).
    pub model: usize,
    /// Seed for this request's Gaussian input vector.
    pub seed: u64,
}

/// Expand the spec into its full time-sorted arrival list. Pure: same
/// spec (seed, rates, duration, load factor) ⇒ identical plan, bit for
/// bit, regardless of thread counts or scheduling.
pub fn plan(spec: &ScenarioSpec) -> Vec<PlannedArrival> {
    let mut all = Vec::new();
    for (ti, tenant) in spec.tenants.iter().enumerate() {
        let schedule_seed = derive_seed(spec.seed, &format!("{}/arrivals", tenant.name), 0);
        let times =
            tenant.process.scaled(spec.load_factor).schedule(schedule_seed, spec.duration);
        let zipf = Zipf::new(tenant.models.len(), tenant.zipf);
        let mut pick = Pcg64::with_stream(
            derive_seed(spec.seed, &format!("{}/models", tenant.name), 0),
            MODEL_PICK_STREAM,
        );
        for (i, &at) in times.iter().enumerate() {
            all.push(PlannedArrival {
                at,
                tenant: ti,
                model: zipf.sample(&mut pick),
                seed: derive_seed(spec.seed, &tenant.name, i as u64),
            });
        }
    }
    all.sort_by(|a, b| a.at.total_cmp(&b.at).then_with(|| a.tenant.cmp(&b.tenant)));
    all
}

/// FNV-1a over the little-endian bytes of one request vector.
fn request_digest(x: &[f32]) -> u64 {
    let mut h = Fnv1a::new();
    for v in x {
        h.update(&v.to_le_bytes());
    }
    h.finish()
}

/// How to drive a planned scenario.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// Submitter threads pacing the schedule (arrivals are interleaved
    /// round-robin so each thread's slice stays time-ordered).
    pub submitters: usize,
    /// Cap on arrivals actually driven (the soak's fast-mode knob);
    /// `None` drives the whole schedule.
    pub max_requests: Option<usize>,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions { submitters: 4, max_requests: None }
    }
}

/// One tenant's outcome over a scenario run.
#[derive(Debug, Clone)]
pub struct TenantOutcome {
    pub tenant: String,
    /// Arrivals the plan scheduled for this tenant.
    pub offered: usize,
    /// Admitted against the model as addressed.
    pub admitted: usize,
    /// Rerouted to the degrade sibling (and answered from it).
    pub degraded: usize,
    /// Shed at admission or at the queue deadline.
    pub shed: usize,
    /// Non-shed errors (model failure, shutdown).
    pub errored: usize,
    /// Answered with an output vector.
    pub completed: usize,
    /// Seconds, scheduled arrival → response, over completed requests.
    pub p50: f64,
    pub p99: f64,
    /// The tenant's deadline/SLO target in ms, when configured.
    pub slo_ms: Option<f64>,
}

impl TenantOutcome {
    /// `None` without a configured SLO, else whether p99 met it.
    pub fn slo_met(&self) -> Option<bool> {
        self.slo_ms.map(|slo| self.p99 * 1e3 <= slo)
    }
}

/// What one scenario run did, process-wide and per tenant.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    pub name: String,
    /// The spec's `load_factor` this run executed at.
    pub load_factor: f64,
    /// Wall time, first submission to last response.
    pub seconds: f64,
    pub offered: usize,
    pub admitted: usize,
    pub degraded: usize,
    pub shed: usize,
    pub errored: usize,
    pub completed: usize,
    /// Seconds, scheduled arrival → response, over completed requests.
    pub p50: f64,
    pub p99: f64,
    /// Order-independent fingerprint of the request-vector multiset
    /// (wrapping sum of per-request FNV-1a digests): equal across runs
    /// ⇔ the same vectors were submitted, however threads interleaved.
    pub vectors_hash: u64,
    pub tenants: Vec<TenantOutcome>,
}

impl ScenarioReport {
    pub fn offered_per_sec(&self) -> f64 {
        self.offered as f64 / self.seconds.max(1e-9)
    }

    /// Useful throughput: completed requests only (degraded answers
    /// count — they carried an output with a priced error; sheds and
    /// failures don't).
    pub fn goodput_per_sec(&self) -> f64 {
        self.completed as f64 / self.seconds.max(1e-9)
    }

    /// Fraction of offered load shed (admission + deadline).
    pub fn shed_rate(&self) -> f64 {
        if self.offered == 0 {
            0.0
        } else {
            self.shed as f64 / self.offered as f64
        }
    }

    /// Fraction of offered load answered from a degrade sibling.
    pub fn degraded_rate(&self) -> f64 {
        if self.offered == 0 {
            0.0
        } else {
            self.degraded as f64 / self.offered as f64
        }
    }

    /// Per-tenant outcome table (the client-side view; the server-side
    /// twin is [`ServeMetrics::tenant_table`](crate::serve::ServeMetrics::tenant_table)).
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            format!("Scenario {} @ {:.2}x load", self.name, self.load_factor),
            &[
                "tenant",
                "offered",
                "admitted",
                "degraded",
                "shed",
                "errored",
                "completed",
                "p50 ms",
                "p99 ms",
                "SLO p99 ms",
                "SLO",
            ],
        );
        for o in &self.tenants {
            let (target, verdict) = match (o.slo_ms, o.slo_met()) {
                (Some(slo), Some(met)) => {
                    (format!("{slo:.1}"), if met { "met" } else { "MISS" }.to_string())
                }
                _ => ("-".to_string(), "-".to_string()),
            };
            t.row(&[
                o.tenant.clone(),
                o.offered.to_string(),
                o.admitted.to_string(),
                o.degraded.to_string(),
                o.shed.to_string(),
                o.errored.to_string(),
                o.completed.to_string(),
                format!("{:.3}", o.p50 * 1e3),
                format!("{:.3}", o.p99 * 1e3),
                target,
                verdict,
            ]);
        }
        t
    }
}

/// One in-flight request, handed from a submitter to the collector.
struct InFlight {
    tenant: usize,
    at: f64,
    outcome: Admission,
    pending: crate::serve::batcher::PendingResponse,
}

/// One finished request, as the collector saw it.
struct Done {
    tenant: usize,
    latency: f64,
    outcome: Admission,
    err: Option<RequestError>,
}

/// Drive `spec` against `server`, open loop. Models (including degrade
/// siblings) are warm-loaded before the clock starts; a bad checkpoint
/// fails here, not mid-run. Client-side thread panics surface as `Err`,
/// never as a poisoned report — "zero client-visible panics" is a
/// scenario-suite invariant.
pub fn run_scenario(
    server: &Arc<Server>,
    spec: &ScenarioSpec,
    opts: &EngineOptions,
) -> Result<ScenarioReport> {
    anyhow::ensure!(!spec.tenants.is_empty(), "scenario has no tenants");
    let mut dims: HashMap<PathBuf, usize> = HashMap::new();
    for path in spec.all_paths() {
        let dim = server
            .model(&path)
            .with_context(|| format!("warm-loading {}", path.display()))?
            .input_dim();
        dims.insert(path, dim);
    }
    let mut arrivals = plan(spec);
    if let Some(cap) = opts.max_requests {
        arrivals.truncate(cap);
    }
    let offered = arrivals.len();
    let mut offered_by_tenant = vec![0usize; spec.tenants.len()];
    for a in &arrivals {
        offered_by_tenant[a.tenant] += 1;
    }
    // (tenant name, model paths, model dims) — the slice submitters need.
    let tenants: Arc<Vec<(String, Vec<(PathBuf, usize)>)>> = Arc::new(
        spec.tenants
            .iter()
            .map(|t| {
                let models =
                    t.models.iter().map(|p| (p.clone(), dims[p])).collect::<Vec<_>>();
                (t.name.clone(), models)
            })
            .collect(),
    );
    let arrivals = Arc::new(arrivals);
    let (tx, rx) = channel::<InFlight>();
    let start = Instant::now();

    let n_submitters = opts.submitters.max(1);
    let mut submitters = Vec::with_capacity(n_submitters);
    for s in 0..n_submitters {
        let server = server.clone();
        let arrivals = arrivals.clone();
        let tenants = tenants.clone();
        let tx = tx.clone();
        submitters.push(std::thread::spawn(move || -> Result<u64, String> {
            let mut digest_sum = 0u64;
            let mut idx = s;
            while idx < arrivals.len() {
                let a = arrivals[idx];
                idx += n_submitters;
                let target = start + Duration::from_secs_f64(a.at);
                let now = Instant::now();
                if target > now {
                    std::thread::sleep(target - now);
                }
                let (name, models) = &tenants[a.tenant];
                let (path, dim) = &models[a.model];
                let mut x = vec![0f32; *dim];
                GaussianSource::new(a.seed).fill_f32(&mut x);
                digest_sum = digest_sum.wrapping_add(request_digest(&x));
                let sub = server.submit_tenant(path, name, x).map_err(|e| e.to_string())?;
                let _ = tx.send(InFlight {
                    tenant: a.tenant,
                    at: a.at,
                    outcome: sub.outcome,
                    pending: sub.response,
                });
            }
            Ok(digest_sum)
        }));
    }
    drop(tx);

    // Collector: poll in-flight responses so submitters never block on
    // waits (that would close the loop).
    let collector = std::thread::spawn(move || -> Vec<Done> {
        let mut pending: Vec<InFlight> = Vec::new();
        let mut done: Vec<Done> = Vec::new();
        let mut open = true;
        loop {
            while open {
                match rx.try_recv() {
                    Ok(inflight) => pending.push(inflight),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => open = false,
                }
            }
            let mut progressed = false;
            let mut i = 0;
            while i < pending.len() {
                match pending[i].pending.try_wait() {
                    Some(result) => {
                        let f = pending.swap_remove(i);
                        let latency = (start.elapsed().as_secs_f64() - f.at).max(0.0);
                        done.push(Done {
                            tenant: f.tenant,
                            latency,
                            outcome: f.outcome,
                            err: result.err(),
                        });
                        progressed = true;
                    }
                    None => i += 1,
                }
            }
            if !open && pending.is_empty() {
                return done;
            }
            if !progressed {
                std::thread::sleep(Duration::from_micros(200));
            }
        }
    });

    let mut vectors_hash = 0u64;
    for handle in submitters {
        let digest = handle
            .join()
            .map_err(|_| anyhow::anyhow!("scenario submitter thread panicked"))?
            .map_err(anyhow::Error::msg)?;
        vectors_hash = vectors_hash.wrapping_add(digest);
    }
    let done = collector
        .join()
        .map_err(|_| anyhow::anyhow!("scenario collector thread panicked"))?;
    let seconds = start.elapsed().as_secs_f64();

    // Per-tenant bookkeeping.
    let n_tenants = spec.tenants.len();
    let mut admitted = vec![0usize; n_tenants];
    let mut degraded = vec![0usize; n_tenants];
    let mut shed = vec![0usize; n_tenants];
    let mut errored = vec![0usize; n_tenants];
    let mut latencies: Vec<Vec<f64>> = vec![Vec::new(); n_tenants];
    for d in &done {
        match d.outcome {
            Admission::Admitted => admitted[d.tenant] += 1,
            Admission::Degraded => degraded[d.tenant] += 1,
            Admission::Shed => {}
        }
        match &d.err {
            None => latencies[d.tenant].push(d.latency),
            Some(e) if e.is_shed() => shed[d.tenant] += 1,
            Some(_) => errored[d.tenant] += 1,
        }
    }
    let mut all_latencies: Vec<f64> = Vec::new();
    let mut tenants_out = Vec::with_capacity(n_tenants);
    for (ti, tenant) in spec.tenants.iter().enumerate() {
        let mut lats = std::mem::take(&mut latencies[ti]);
        all_latencies.extend_from_slice(&lats);
        lats.sort_by(f64::total_cmp);
        // Invariant: completed + errored + shed == offered (admission
        // sheds and deadline sheds both answer with a Shed error;
        // `admitted`/`degraded` record the admission decision, so a
        // deadline-shed request counts in both admitted and shed).
        tenants_out.push(TenantOutcome {
            tenant: tenant.name.clone(),
            offered: offered_by_tenant[ti],
            admitted: admitted[ti],
            degraded: degraded[ti],
            shed: shed[ti],
            errored: errored[ti],
            completed: lats.len(),
            p50: percentile(&lats, 0.50),
            p99: percentile(&lats, 0.99),
            slo_ms: tenant.deadline_ms,
        });
    }
    all_latencies.sort_by(f64::total_cmp);
    let completed = all_latencies.len();
    let total = |f: fn(&TenantOutcome) -> usize| tenants_out.iter().map(f).sum::<usize>();
    if crate::obs::enabled() {
        use crate::obs::span::ArgVal;
        crate::obs::span::record(
            "scenario",
            start,
            vec![
                ("name", ArgVal::Str(spec.name.clone())),
                ("offered", ArgVal::U64(offered as u64)),
                ("completed", ArgVal::U64(completed as u64)),
            ],
        );
    }
    Ok(ScenarioReport {
        name: spec.name.clone(),
        load_factor: spec.load_factor,
        seconds,
        offered,
        admitted: total(|t| t.admitted),
        degraded: total(|t| t.degraded),
        shed: total(|t| t.shed),
        errored: total(|t| t.errored),
        completed,
        p50: percentile(&all_latencies, 0.50),
        p99: percentile(&all_latencies, 0.99),
        vectors_hash,
        tenants: tenants_out,
    })
}

/// Sweep `spec` across offered-load multipliers, a fresh server per
/// point (so one point's backlog can't poison the next), and return the
/// degradation curve as `(factor, report)` pairs.
pub fn degradation_curve<F>(
    make_server: F,
    spec: &ScenarioSpec,
    factors: &[f64],
    opts: &EngineOptions,
) -> Result<Vec<(f64, ScenarioReport)>>
where
    F: Fn() -> Arc<Server>,
{
    let mut curve = Vec::with_capacity(factors.len());
    for &factor in factors {
        let server = make_server();
        let report = run_scenario(&server, &spec.scaled(factor), opts)?;
        curve.push((factor, report));
    }
    Ok(curve)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::scenario::spec::ScenarioSpec;

    fn two_tenant_spec() -> ScenarioSpec {
        ScenarioSpec::parse(
            r#"
name = "unit"
seed = 5
duration = 0.5

[tenant.a]
models = ["x.tenz", "y.tenz"]
rate = 200.0
zipf = 1.0

[tenant.b]
models = ["x.tenz"]
arrivals = "diurnal"
rate = 100.0
"#,
        )
        .unwrap()
    }

    #[test]
    fn plan_is_deterministic_sorted_and_complete() {
        let spec = two_tenant_spec();
        let p1 = plan(&spec);
        let p2 = plan(&spec);
        assert_eq!(p1, p2);
        assert!(!p1.is_empty());
        assert!(p1.windows(2).all(|w| w[0].at <= w[1].at), "not time-sorted");
        assert!(p1.iter().any(|a| a.tenant == 0) && p1.iter().any(|a| a.tenant == 1));
        // Zipf over tenant a's two models: hot model 0 dominates.
        let hot = p1.iter().filter(|a| a.tenant == 0 && a.model == 0).count();
        let cold = p1.iter().filter(|a| a.tenant == 0 && a.model == 1).count();
        assert!(hot > cold, "zipf head {hot} vs tail {cold}");
        // Per-request seeds are unique.
        let mut seeds: Vec<u64> = p1.iter().map(|a| a.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), p1.len(), "request seeds collide");
    }

    #[test]
    fn load_factor_scales_the_plan() {
        let spec = two_tenant_spec();
        let base = plan(&spec).len() as f64;
        let heavy = plan(&spec.scaled(4.0)).len() as f64;
        assert!(heavy > 2.5 * base, "4x load produced {heavy} vs {base} arrivals");
    }
}
