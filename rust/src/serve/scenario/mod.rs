//! `serve::scenario` — open-loop traffic scenarios with per-tenant SLOs.
//!
//! Where [`traffic`](super::traffic) drives closed-loop uniform load
//! (clients wait on their own backlog, so the server never truly
//! drowns), this module generates **open-loop** traffic: arrivals come
//! from a deterministic seeded process and keep coming no matter how
//! slow responses are — the regime where admission control, fair
//! queueing, and graceful degradation actually get exercised. The
//! pieces:
//!
//! * [`arrivals`] — seeded arrival processes (Poisson, bursty on/off
//!   Markov, diurnal sinusoid-thinned) that are pure functions of
//!   `(process, seed, duration)`, plus the [`Zipf`] hot-key sampler.
//! * [`spec`] — the TOML scenario description (`rsic traffic
//!   --scenario f.toml`): per-tenant model sets, arrival shapes, DRR
//!   weights, queue quotas, deadlines, and degrade siblings.
//! * [`engine`] — [`plan`] expands a spec into a time-sorted arrival
//!   list before any thread runs; [`run_scenario`] paces it against the
//!   wall clock and reports per-tenant offered/admitted/degraded/shed
//!   plus p50/p99-vs-SLO; [`degradation_curve`] sweeps the load factor
//!   for the soak suite.
//!
//! The scenario suite in `tests/traffic_scenarios.rs` pins the
//! contract: deterministic arrivals and request multisets, bounded shed
//! under overload with zero client-visible panics, fair-queueing p99
//! isolation, and degradation-mode goodput with the paper's
//! ‖Δy‖ ≤ ‖W−UVᵀ‖₂‖x‖₂ bound on every degraded answer.

pub mod arrivals;
pub mod engine;
pub mod spec;

pub use arrivals::{ArrivalProcess, Zipf};
pub use engine::{
    degradation_curve, plan, run_scenario, EngineOptions, PlannedArrival, ScenarioReport,
    TenantOutcome,
};
pub use spec::{ScenarioSpec, TenantSpec};
