//! `serve` — batched low-rank inference over compressed checkpoints.
//!
//! The deployment half of the compression story (and of the ROADMAP's
//! serve-heavy-traffic north star): everything upstream of this module
//! *produces* factored checkpoints; this module *runs* them. A factored
//! layer answers `y = U(Vᵀx)` in k(C+D) MACs against the dense C·D, so at
//! the paper's α ≤ 0.3 operating points a served model is both smaller
//! and faster — provided requests are batched well enough that GEMM, not
//! per-request overhead, dominates. The pieces:
//!
//! * [`kernel`]  — per-layer execution kernels ([`DenseLinear`] `Wx`,
//!   [`FactoredLinear`] `U(Vᵀx)`) and the [`ModelKernels`] chain loaded
//!   from any [`WeightSource`](crate::io::checkpoint::WeightSource).
//! * [`batcher`] — the micro-batching queue: coalesce up to `max_batch`
//!   requests or `max_wait` of arrivals into one batched GEMM pass.
//! * [`server`]  — the engine: one persistent
//!   [`WorkerPool`](crate::coordinator::WorkerPool), an LRU model cache,
//!   one batcher per cached model.
//! * [`cache`]   — LRU model cache keyed by checkpoint path + the mtime
//!   snapshot of every backing file (container, or manifest + shards), so
//!   touching any shard of a sharded checkpoint invalidates its kernels.
//! * [`metrics`] — request/batch/latency/cache counters rendered through
//!   [`report::table`](crate::report::table); latencies live in a bounded
//!   reservoir so a long-lived server's memory stays O(1).
//! * [`traffic`] — the synthetic load generator shared by `rsic serve`
//!   and the throughput bench.
//!
//! Invariants (tested in `tests/serve.rs`):
//!
//! * A factored forward pass equals the dense pass exactly (up to fp
//!   roundoff) at full rank, and within ‖W − UVᵀ‖₂·‖x‖₂ below it.
//! * N concurrent requests produce ≪ N batches; a lone request still
//!   flushes after `max_wait`.
//! * Every accepted request is answered, even across server shutdown.

pub mod batcher;
pub mod cache;
pub mod kernel;
pub mod metrics;
pub mod server;
pub mod traffic;

pub use batcher::{Batcher, BatcherConfig, PendingResponse};
pub use cache::{ModelCache, ModelKey};
pub use kernel::{DenseLinear, FactoredLinear, LinearKernel, ModelKernels, ServeLayer};
pub use metrics::{LatencyQuantiles, ServeMetrics};
pub use server::{ServeConfig, Server};
pub use traffic::{drive, TrafficReport};
