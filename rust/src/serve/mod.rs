//! `serve` — batched low-rank inference over compressed checkpoints.
//!
//! The deployment half of the compression story (and of the ROADMAP's
//! serve-heavy-traffic north star): everything upstream of this module
//! *produces* factored checkpoints; this module *runs* them. A factored
//! layer answers `y = U(Vᵀx)` in k(C+D) MACs against the dense C·D, so at
//! the paper's α ≤ 0.3 operating points a served model is both smaller
//! and faster — provided requests are batched well enough that GEMM, not
//! per-request overhead, dominates. The pieces:
//!
//! * [`kernel`]  — per-layer execution kernels ([`DenseLinear`] `Wx`,
//!   [`FactoredLinear`] `U(Vᵀx)`, [`QuantFactoredLinear`] over i8 codes)
//!   and the [`ModelKernels`] chain loaded from any
//!   [`WeightSource`](crate::io::checkpoint::WeightSource). Bias+ReLU run
//!   inside the GEMM epilogue; the chain reuses scratch across layers.
//! * [`batcher`] — the micro-batching queue: coalesce up to `max_batch`
//!   requests or `max_wait` of arrivals into one batched GEMM pass.
//! * [`server`]  — the engine: one persistent
//!   [`WorkerPool`](crate::coordinator::WorkerPool), an LRU model cache,
//!   one batcher per cached model.
//! * [`cache`]   — LRU model cache keyed by checkpoint path + the mtime
//!   snapshot of every backing file (container, or manifest + shards), so
//!   touching any shard of a sharded checkpoint invalidates its kernels.
//! * [`metrics`] — request/batch/latency/cache counters rendered through
//!   [`report::table`](crate::report::table); latencies live in bounded
//!   per-model reservoirs so a long-lived server's memory stays O(1) and
//!   p50/p99 report per checkpoint, not per process.
//! * [`traffic`] — the closed-loop synthetic load generator shared by
//!   `rsic serve` and the throughput bench.
//! * [`scenario`] — the open-loop scenario engine (`rsic traffic`):
//!   seeded Poisson/bursty/diurnal arrivals, multi-tenant mixes with
//!   Zipf hot-key skew, and the soak/degradation-curve driver. Pairs
//!   with the batcher's per-tenant admission control: quotas and
//!   deadlines shed, degrade siblings serve overflow at the paper's
//!   priced accuracy cost, deficit-round-robin drains keep a flooding
//!   tenant from starving the rest.
//! * [`cluster`] — multi-host serving: placement planner, wire protocol,
//!   worker processes, and the routing front end the micro-batcher
//!   drains into (with failover back to local execution).
//!
//! Invariants (tested in `tests/serve.rs` and `tests/cluster.rs`):
//!
//! * A factored forward pass equals the dense pass exactly (up to fp
//!   roundoff) at full rank, and within ‖W − UVᵀ‖₂·‖x‖₂ below it.
//! * N concurrent requests produce ≪ N batches; a lone request still
//!   flushes after `max_wait`.
//! * Every accepted request is answered, even across server shutdown —
//!   and, under routed serving, even across worker death (failover).
//! * Routed outputs are bit-identical to single-process serving.

pub mod batcher;
pub mod cache;
pub mod cluster;
pub mod kernel;
pub mod metrics;
pub mod scenario;
pub mod server;
pub mod traffic;

pub use batcher::{
    BatchExecutor, Batcher, BatcherConfig, LocalExecutor, PendingResponse, RequestError,
    TenantPolicy, DEFAULT_TENANT,
};
pub use cache::{ModelCache, ModelKey};
pub use cluster::{PlacementMode, PlacementPlan, RoutedExecutor, Router, RouterConfig};
pub use kernel::{
    DenseLinear, FactoredLinear, LinearKernel, ModelKernels, QuantFactoredLinear, ServeLayer,
};
pub use metrics::{LatencyQuantiles, ServeMetrics, TenantCounters, TenantSnapshot};
pub use scenario::{ArrivalProcess, EngineOptions, ScenarioReport, ScenarioSpec};
pub use server::{Admission, ServeConfig, Server, TenantSubmission};
pub use traffic::{drive, TrafficReport};
