//! Serving metrics: request/batch counters, latency quantiles, cache hit
//! rate — rendered through [`report::table`](crate::report::table) so
//! `rsic serve` prints the same aligned tables as the paper reports.
//!
//! Latencies are tracked **per model** (one bounded Algorithm-R
//! reservoir per checkpoint), so a process serving many checkpoints
//! reports p50/p99 per checkpoint, not one blended distribution — the
//! same per-model numbers the cluster `Stats` wire frame exports.

use super::cache::ModelCache;
use crate::bench::stats::percentile;
use crate::report::Table;
use crate::rng::Pcg64;
use crate::util::lock_recover;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Latency quantiles over recorded requests (seconds). Computed from a
/// bounded reservoir sample, so `p50`/`p99` are estimates once more than
/// [`LATENCY_RESERVOIR`] requests have been recorded; `n` counts every
/// request ever recorded.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyQuantiles {
    pub n: usize,
    pub p50: f64,
    pub p99: f64,
    pub max: f64,
}

/// Latency samples kept for quantiles, per model. A long-lived server
/// records one latency per answered request forever; a fixed-size
/// uniform reservoir (Vitter's Algorithm R) keeps memory and render cost
/// O(1) per model instead of growing per request, and the number of
/// per-model reservoirs is itself capped at [`MAX_MODEL_RESERVOIRS`].
const LATENCY_RESERVOIR: usize = 4096;

/// Per-model reservoirs kept at most. The map tracks models actually
/// serving traffic: past this bound the least-recently-updated entry is
/// evicted (the process-wide reservoir keeps the full history
/// regardless), so a server cycling through many distinct checkpoint
/// paths over months stays O(1) in metric memory like the pre-cluster
/// code was.
const MAX_MODEL_RESERVOIRS: usize = 64;

#[derive(Debug)]
struct LatencyReservoir {
    samples: Vec<f64>,
    /// Total latencies ever recorded (the reservoir's denominator).
    seen: u64,
    rng: Pcg64,
    /// Recency stamp (from `ServeMetrics::touch_counter`) driving the
    /// least-recently-updated eviction above.
    touched: u64,
}

impl Default for LatencyReservoir {
    fn default() -> Self {
        LatencyReservoir { samples: Vec::new(), seen: 0, rng: Pcg64::new(0x5e7e_1a7e), touched: 0 }
    }
}

impl LatencyReservoir {
    /// Seed derived from the model name so a multi-model process keeps
    /// per-model reservoirs deterministic and independent.
    fn for_model(model: &str) -> Self {
        let mut h = crate::io::tenz::Fnv1a::new();
        h.update(model.as_bytes());
        LatencyReservoir {
            samples: Vec::new(),
            seen: 0,
            rng: Pcg64::new(h.finish() ^ 0x5e7e_1a7e),
            touched: 0,
        }
    }

    fn record(&mut self, secs: f64) {
        self.seen += 1;
        if self.samples.len() < LATENCY_RESERVOIR {
            self.samples.push(secs);
        } else {
            let j = self.rng.next_below(self.seen) as usize;
            if j < LATENCY_RESERVOIR {
                self.samples[j] = secs;
            }
        }
    }

    fn quantiles(&self) -> LatencyQuantiles {
        let mut samples = self.samples.clone();
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        LatencyQuantiles {
            n: self.seen as usize,
            p50: percentile(&samples, 0.50),
            p99: percentile(&samples, 0.99),
            max: samples.last().copied().unwrap_or(0.0),
        }
    }
}

/// Per-tenant admission counters. `offered` is everything the tenant
/// asked for; `admitted`, `degraded`, and `shed` partition the admission
/// decision, while `deadline_shed` counts admitted requests later
/// dropped in queue past the tenant's deadline (so they land in both
/// `admitted` and `deadline_shed`).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TenantCounters {
    pub offered: u64,
    pub admitted: u64,
    pub degraded: u64,
    pub shed: u64,
    pub deadline_shed: u64,
}

#[derive(Debug)]
struct TenantRow {
    counters: TenantCounters,
    latency: LatencyReservoir,
    /// The p99 latency target (seconds) this tenant is judged against.
    slo_secs: Option<f64>,
    touched: u64,
}

/// One tenant's metrics row, snapshotted: admission counters, latency
/// quantiles, and the SLO verdict — what the tenant table and the
/// cluster `Stats` frame carry.
#[derive(Debug, Clone)]
pub struct TenantSnapshot {
    pub tenant: String,
    pub counters: TenantCounters,
    pub latency: LatencyQuantiles,
    pub slo_secs: Option<f64>,
}

impl TenantSnapshot {
    /// `None` when no SLO is configured; otherwise whether observed p99
    /// meets the target.
    pub fn slo_met(&self) -> Option<bool> {
        self.slo_secs.map(|slo| self.latency.p99 <= slo)
    }
}

/// Counters shared by the batchers of one server process.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    /// Requests accepted into a batcher queue.
    pub requests: AtomicU64,
    /// Requests answered with an output vector.
    pub responses: AtomicU64,
    /// Requests refused up front (wrong input width, shutdown).
    pub rejected: AtomicU64,
    /// Requests the admission controller *chose* not to serve: global
    /// queue overload, tenant quota with no degrade path left, or a
    /// queue-deadline drop. Kept apart from `rejected` — shed is policy,
    /// rejection is a broken request.
    pub shed: AtomicU64,
    /// Batched GEMM passes executed.
    pub batches: AtomicU64,
    /// Total inputs across executed batches (occupancy numerator).
    pub batched_inputs: AtomicU64,
    /// Batches answered by a remote cluster worker (routed serving).
    pub routed_batches: AtomicU64,
    /// Batches that fell back to local in-process execution after the
    /// routed path failed (worker death, wire corruption).
    pub failovers: AtomicU64,
    /// Bounded per-model reservoirs of request latencies
    /// (enqueue → response), keyed by checkpoint label.
    models: Mutex<BTreeMap<String, LatencyReservoir>>,
    /// One process-wide reservoir fed by every request regardless of
    /// model. The per-model reservoirs cannot stand in for it: once a
    /// busy model's reservoir saturates, a union of per-model samples
    /// over-weights quiet models, so the aggregate quantiles come from
    /// this genuinely uniform sample of the whole request history.
    global: Mutex<LatencyReservoir>,
    /// Per-tenant admission counters, latency reservoirs, and SLO
    /// targets, keyed by tenant name — bounded like `models`.
    tenants: Mutex<BTreeMap<String, TenantRow>>,
    /// Monotone stamp for reservoir recency (eviction order).
    touch_counter: AtomicU64,
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// One batch of `n` coalesced inputs was executed.
    pub fn record_batch(&self, n: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_inputs.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// One request against `model` completed, `secs` after it was
    /// enqueued. The sample lands in that model's latency reservoir
    /// (always, while it has room; with probability reservoir/seen after
    /// — Algorithm R, so each reservoir stays a uniform sample of its
    /// model's whole history).
    pub fn record_latency(&self, model: &str, secs: f64) {
        self.record_latency_n(model, secs, 1)
    }

    /// Record `n` requests against `model` that shared one latency (a
    /// whole routed batch, say) in a single lock pass — the worker's
    /// per-batch entry point, so a 4096-row batch costs two lock
    /// acquisitions, not 8192.
    pub fn record_latency_n(&self, model: &str, secs: f64, n: usize) {
        if n == 0 {
            return;
        }
        self.responses.fetch_add(n as u64, Ordering::Relaxed);
        let stamp = self.touch_counter.fetch_add(1, Ordering::Relaxed) + 1;
        {
            let mut map = lock_recover(&self.models);
            if !map.contains_key(model) && map.len() >= MAX_MODEL_RESERVOIRS {
                if let Some(evict) =
                    map.iter().min_by_key(|(_, r)| r.touched).map(|(k, _)| k.clone())
                {
                    map.remove(&evict);
                }
            }
            let r = map
                .entry(model.to_string())
                .or_insert_with(|| LatencyReservoir::for_model(model));
            r.touched = stamp;
            for _ in 0..n {
                r.record(secs);
            }
        }
        let mut global = lock_recover(&self.global);
        for _ in 0..n {
            global.record(secs);
        }
    }

    /// Touch-or-create the tenant row, evicting the least-recently
    /// updated one past the bound (same policy as the model reservoirs).
    fn with_tenant<F: FnOnce(&mut TenantRow)>(&self, tenant: &str, f: F) {
        let stamp = self.touch_counter.fetch_add(1, Ordering::Relaxed) + 1;
        let mut map = lock_recover(&self.tenants);
        if !map.contains_key(tenant) && map.len() >= MAX_MODEL_RESERVOIRS {
            if let Some(evict) = map.iter().min_by_key(|(_, r)| r.touched).map(|(k, _)| k.clone())
            {
                map.remove(&evict);
            }
        }
        let row = map.entry(tenant.to_string()).or_insert_with(|| TenantRow {
            counters: TenantCounters::default(),
            latency: LatencyReservoir::for_model(tenant),
            slo_secs: None,
            touched: 0,
        });
        row.touched = stamp;
        f(row);
    }

    /// One request arrived addressed to `tenant` (counted before any
    /// admission decision).
    pub fn tenant_offered(&self, tenant: &str) {
        self.with_tenant(tenant, |r| r.counters.offered += 1);
    }

    /// The request was admitted into the tenant's queue as submitted.
    pub fn tenant_admitted(&self, tenant: &str) {
        self.with_tenant(tenant, |r| r.counters.admitted += 1);
    }

    /// The request was rerouted to the tenant's degrade sibling — served,
    /// but at a known accuracy cost; counted apart from sheds.
    pub fn tenant_degraded(&self, tenant: &str) {
        self.with_tenant(tenant, |r| r.counters.degraded += 1);
    }

    /// The request was shed at admission. Also counts into the
    /// process-wide [`shed`](Self::shed) total, so callers bump neither
    /// separately.
    pub fn tenant_shed(&self, tenant: &str) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        self.with_tenant(tenant, |r| r.counters.shed += 1);
    }

    /// An admitted request was dropped in queue past the tenant deadline.
    /// The caller (the batcher's drain) bumps the global `shed` counter
    /// at the drop site, so this only keeps the tenant's own books.
    pub fn tenant_deadline_shed(&self, tenant: &str) {
        self.with_tenant(tenant, |r| r.counters.deadline_shed += 1);
    }

    /// Declare the p99 latency target (seconds) `tenant` is judged
    /// against in the tenant table.
    pub fn set_tenant_slo(&self, tenant: &str, secs: f64) {
        self.with_tenant(tenant, |r| r.slo_secs = Some(secs));
    }

    /// One of `tenant`'s requests completed `secs` after submission.
    pub fn record_tenant_latency(&self, tenant: &str, secs: f64) {
        self.with_tenant(tenant, |r| r.latency.record(secs));
    }

    /// Snapshot every tenant row (sorted by tenant name).
    pub fn tenant_snapshots(&self) -> Vec<TenantSnapshot> {
        let map = lock_recover(&self.tenants);
        map.iter()
            .map(|(name, r)| TenantSnapshot {
                tenant: name.clone(),
                counters: r.counters,
                latency: r.latency.quantiles(),
                slo_secs: r.slo_secs,
            })
            .collect()
    }

    /// The per-tenant traffic table: offered vs admitted vs degraded vs
    /// shed, and p50/p99 against the SLO target. `None` until some
    /// tenant-addressed traffic has been recorded.
    pub fn tenant_table(&self) -> Option<Table> {
        let snaps = self.tenant_snapshots();
        if snaps.is_empty() {
            return None;
        }
        let mut t = Table::new(
            "Per-tenant traffic",
            &[
                "tenant",
                "offered",
                "admitted",
                "degraded",
                "shed",
                "deadline-shed",
                "p50 ms",
                "p99 ms",
                "SLO p99 ms",
                "SLO",
            ],
        );
        for s in snaps {
            let (target, verdict) = match s.slo_secs {
                Some(slo) => (
                    format!("{:.1}", slo * 1e3),
                    if s.latency.p99 <= slo { "met" } else { "MISS" }.to_string(),
                ),
                None => ("-".to_string(), "-".to_string()),
            };
            t.row(&[
                s.tenant.clone(),
                s.counters.offered.to_string(),
                s.counters.admitted.to_string(),
                s.counters.degraded.to_string(),
                s.counters.shed.to_string(),
                s.counters.deadline_shed.to_string(),
                format!("{:.3}", s.latency.p50 * 1e3),
                format!("{:.3}", s.latency.p99 * 1e3),
                target,
                verdict,
            ]);
        }
        Some(t)
    }

    /// Mean inputs per executed batch (1.0 = no coalescing happened).
    pub fn mean_occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_inputs.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Process-wide p50/p99/max request latency from the global
    /// reservoir — a uniform sample over every request regardless of
    /// which model served it (`n` counts all requests ever recorded).
    pub fn latency_quantiles(&self) -> LatencyQuantiles {
        lock_recover(&self.global).quantiles()
    }

    /// Per-model latency quantiles, sorted by model label — what
    /// `rsic serve` prints and the cluster `Stats` frame carries.
    pub fn model_quantiles(&self) -> Vec<(String, LatencyQuantiles)> {
        let map = lock_recover(&self.models);
        map.iter().map(|(name, r)| (name.clone(), r.quantiles())).collect()
    }

    /// Models with at least one recorded latency.
    pub fn models_seen(&self) -> usize {
        lock_recover(&self.models).len()
    }

    /// Render the serving counters (and, when given, the model cache's
    /// hit statistics) as an aligned metric/value table. Latency rows
    /// appear per model, plus a process-wide aggregate when more than one
    /// model has traffic.
    pub fn render(&self, cache: Option<&ModelCache>) -> Table {
        let mut t = Table::new("Serve metrics", &["metric", "value"]);
        let row = |t: &mut Table, k: &str, v: String| {
            t.row(&[k.to_string(), v]);
        };
        row(&mut t, "requests", self.requests.load(Ordering::Relaxed).to_string());
        row(&mut t, "responses", self.responses.load(Ordering::Relaxed).to_string());
        row(&mut t, "rejected", self.rejected.load(Ordering::Relaxed).to_string());
        let shed = self.shed.load(Ordering::Relaxed);
        if shed > 0 {
            row(&mut t, "shed", shed.to_string());
        }
        row(&mut t, "batches", self.batches.load(Ordering::Relaxed).to_string());
        row(&mut t, "mean batch occupancy", format!("{:.2}", self.mean_occupancy()));
        let routed = self.routed_batches.load(Ordering::Relaxed);
        let failovers = self.failovers.load(Ordering::Relaxed);
        if routed > 0 || failovers > 0 {
            row(&mut t, "routed batches", routed.to_string());
            row(&mut t, "failovers to local", failovers.to_string());
        }
        let per_model = self.model_quantiles();
        for (model, lq) in &per_model {
            row(&mut t, &format!("p50 latency [{model}]"), format!("{:.3} ms", lq.p50 * 1e3));
            row(&mut t, &format!("p99 latency [{model}]"), format!("{:.3} ms", lq.p99 * 1e3));
        }
        if per_model.len() != 1 {
            let lq = self.latency_quantiles();
            row(&mut t, "p50 latency", format!("{:.3} ms", lq.p50 * 1e3));
            row(&mut t, "p99 latency", format!("{:.3} ms", lq.p99 * 1e3));
        }
        if let Some(cache) = cache {
            let (h, m) = cache.stats();
            row(&mut t, "model-cache hits", h.to_string());
            row(&mut t, "model-cache misses", m.to_string());
            row(&mut t, "model-cache hit rate", format!("{:.1}%", cache.hit_rate() * 100.0));
            row(&mut t, "model-cache evictions", cache.evictions().to_string());
        }
        t
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        let lq = self.latency_quantiles();
        format!(
            "{} requests in {} batches (occupancy {:.2}); p50 {:.3} ms, p99 {:.3} ms, {} rejected, {} shed",
            self.responses.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_occupancy(),
            lq.p50 * 1e3,
            lq.p99 * 1e3,
            self.rejected.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_and_quantiles() {
        let m = ServeMetrics::new();
        m.record_batch(4);
        m.record_batch(2);
        for secs in [0.001, 0.002, 0.003, 0.004, 0.005, 0.006] {
            m.record_latency("m.tenz", secs);
        }
        assert!((m.mean_occupancy() - 3.0).abs() < 1e-12);
        let lq = m.latency_quantiles();
        assert_eq!(lq.n, 6);
        assert!((lq.p50 - 0.0035).abs() < 1e-9);
        assert!(lq.p99 <= lq.max && lq.max == 0.006);
        let rendered = m.render(None).render();
        assert!(rendered.contains("mean batch occupancy"));
        assert!(rendered.contains("3.00"));
    }

    #[test]
    fn latencies_are_tracked_per_model() {
        let m = ServeMetrics::new();
        for _ in 0..10 {
            m.record_latency("fast.tenz", 0.001);
            m.record_latency("slow.toml", 0.1);
        }
        let per_model = m.model_quantiles();
        assert_eq!(per_model.len(), 2);
        assert_eq!(m.models_seen(), 2);
        let fast = &per_model.iter().find(|(n, _)| n == "fast.tenz").unwrap().1;
        let slow = &per_model.iter().find(|(n, _)| n == "slow.toml").unwrap().1;
        assert_eq!(fast.n, 10);
        assert!((fast.p50 - 0.001).abs() < 1e-9, "fast model p50 {}", fast.p50);
        assert!((slow.p50 - 0.1).abs() < 1e-9, "slow model p50 {}", slow.p50);
        // The blended process aggregate sits between the two models.
        let all = m.latency_quantiles();
        assert_eq!(all.n, 20);
        assert!(all.p50 > fast.p50 && all.p50 <= slow.p50);
        // Both models render their own quantile rows.
        let rendered = m.render(None).render();
        assert!(rendered.contains("p50 latency [fast.tenz]"));
        assert!(rendered.contains("p99 latency [slow.toml]"));
    }

    #[test]
    fn model_reservoir_map_is_bounded() {
        let m = ServeMetrics::new();
        let total = MAX_MODEL_RESERVOIRS + 10;
        for i in 0..total {
            m.record_latency(&format!("m{i}.tenz"), 0.001);
        }
        // Oldest entries evicted; the most recent model survives; the
        // process-wide aggregate keeps the full request history.
        assert_eq!(m.models_seen(), MAX_MODEL_RESERVOIRS);
        let latest = format!("m{}.tenz", total - 1);
        assert!(m.model_quantiles().iter().any(|(n, _)| *n == latest));
        assert_eq!(m.latency_quantiles().n, total);
    }

    #[test]
    fn bulk_record_counts_every_row() {
        let m = ServeMetrics::new();
        m.record_latency_n("m.tenz", 0.002, 5);
        m.record_latency_n("m.tenz", 0.002, 0); // no-op
        let per = m.model_quantiles();
        assert_eq!(per.len(), 1);
        assert_eq!(per[0].1.n, 5);
        assert!((per[0].1.p50 - 0.002).abs() < 1e-12);
        assert_eq!(m.responses.load(Ordering::Relaxed), 5);
        assert_eq!(m.latency_quantiles().n, 5);
    }

    #[test]
    fn latency_reservoir_stays_bounded() {
        let m = ServeMetrics::new();
        let total = LATENCY_RESERVOIR + 500;
        for i in 0..total {
            m.record_latency("one.tenz", i as f64 * 1e-6);
        }
        let lq = m.latency_quantiles();
        // n counts every request; the stored samples stay capped.
        assert_eq!(lq.n, total);
        assert_eq!(
            m.models.lock().unwrap().get("one.tenz").unwrap().samples.len(),
            LATENCY_RESERVOIR
        );
        assert!(lq.p50 > 0.0 && lq.p99 >= lq.p50 && lq.max >= lq.p99);
    }

    #[test]
    fn tenant_rows_track_admission_and_slo() {
        let m = ServeMetrics::new();
        assert!(m.tenant_table().is_none());
        m.set_tenant_slo("gold", 0.010);
        for _ in 0..4 {
            m.tenant_offered("gold");
        }
        m.tenant_admitted("gold");
        m.tenant_admitted("gold");
        m.tenant_degraded("gold");
        m.tenant_shed("gold");
        m.record_tenant_latency("gold", 0.002);
        m.record_tenant_latency("gold", 0.004);
        m.tenant_offered("free");
        m.tenant_shed("free");
        let snaps = m.tenant_snapshots();
        assert_eq!(snaps.len(), 2);
        let gold = snaps.iter().find(|s| s.tenant == "gold").unwrap();
        assert_eq!(
            gold.counters,
            TenantCounters { offered: 4, admitted: 2, degraded: 1, shed: 1, deadline_shed: 0 }
        );
        assert_eq!(gold.slo_met(), Some(true), "p99 {} vs 10ms SLO", gold.latency.p99);
        let free = snaps.iter().find(|s| s.tenant == "free").unwrap();
        assert_eq!(free.slo_met(), None);
        // tenant_shed keeps the process-wide ledger too.
        assert_eq!(m.shed.load(Ordering::Relaxed), 2);
        let rendered = m.tenant_table().unwrap().render();
        assert!(rendered.contains("gold"), "{rendered}");
        assert!(rendered.contains("met"), "{rendered}");
        assert!(m.render(None).render().contains("shed"));
    }

    #[test]
    fn poisoned_metric_locks_keep_recording() {
        // A panic on one request thread while holding a metrics lock must
        // not silence every later sample with a PoisonError.
        let m = std::sync::Arc::new(ServeMetrics::new());
        m.record_latency("m.tenz", 0.001);
        m.tenant_offered("gold");
        let m2 = std::sync::Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _a = m2.models.lock().unwrap();
            let _b = m2.global.lock().unwrap();
            let _c = m2.tenants.lock().unwrap();
            panic!("injected panic while holding metrics locks");
        })
        .join();
        assert!(m.models.lock().is_err(), "models lock should be poisoned");
        m.record_latency("m.tenz", 0.003);
        m.tenant_offered("gold");
        let lq = m.latency_quantiles();
        assert_eq!(lq.n, 2, "both samples must survive the poisoning");
        assert_eq!(m.models_seen(), 1);
        assert_eq!(m.tenant_snapshots()[0].counters.offered, 2);
        assert!(m.render(None).render().contains("p50 latency"));
    }

    #[test]
    fn empty_metrics_render() {
        let m = ServeMetrics::new();
        assert_eq!(m.mean_occupancy(), 0.0);
        assert_eq!(m.latency_quantiles().n, 0);
        assert!(m.model_quantiles().is_empty());
        assert!(m.summary().contains("0 requests"));
    }
}
