//! Serving metrics: request/batch counters, latency quantiles, cache hit
//! rate — rendered through [`report::table`](crate::report::table) so
//! `rsic serve` prints the same aligned tables as the paper reports.

use super::cache::ModelCache;
use crate::bench::stats::percentile;
use crate::report::Table;
use crate::rng::Pcg64;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Latency quantiles over recorded requests (seconds). Computed from a
/// bounded reservoir sample, so `p50`/`p99` are estimates once more than
/// [`LATENCY_RESERVOIR`] requests have been recorded; `n` counts every
/// request ever recorded.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyQuantiles {
    pub n: usize,
    pub p50: f64,
    pub p99: f64,
    pub max: f64,
}

/// Latency samples kept for quantiles. A long-lived server records one
/// latency per answered request forever; a fixed-size uniform reservoir
/// (Vitter's Algorithm R) keeps memory and render cost O(1) instead of
/// growing per request.
const LATENCY_RESERVOIR: usize = 4096;

#[derive(Debug)]
struct LatencyReservoir {
    samples: Vec<f64>,
    /// Total latencies ever recorded (the reservoir's denominator).
    seen: u64,
    rng: Pcg64,
}

impl Default for LatencyReservoir {
    fn default() -> Self {
        LatencyReservoir { samples: Vec::new(), seen: 0, rng: Pcg64::new(0x5e7e_1a7e) }
    }
}

/// Counters shared by the batchers of one server process.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    /// Requests accepted into a batcher queue.
    pub requests: AtomicU64,
    /// Requests answered with an output vector.
    pub responses: AtomicU64,
    /// Requests refused up front (wrong input width, shutdown).
    pub rejected: AtomicU64,
    /// Batched GEMM passes executed.
    pub batches: AtomicU64,
    /// Total inputs across executed batches (occupancy numerator).
    pub batched_inputs: AtomicU64,
    /// Bounded reservoir of per-request latencies (enqueue → response).
    latencies: Mutex<LatencyReservoir>,
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// One batch of `n` coalesced inputs was executed.
    pub fn record_batch(&self, n: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_inputs.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// One request completed, `secs` after it was enqueued. The sample
    /// lands in the latency reservoir (always, while it has room; with
    /// probability reservoir/seen after — Algorithm R, so the reservoir
    /// stays a uniform sample of the whole history).
    pub fn record_latency(&self, secs: f64) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        let mut r = self.latencies.lock().unwrap();
        r.seen += 1;
        if r.samples.len() < LATENCY_RESERVOIR {
            r.samples.push(secs);
        } else {
            let seen = r.seen;
            let j = r.rng.next_below(seen) as usize;
            if j < LATENCY_RESERVOIR {
                r.samples[j] = secs;
            }
        }
    }

    /// Mean inputs per executed batch (1.0 = no coalescing happened).
    pub fn mean_occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_inputs.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// p50/p99/max request latency (reservoir estimates; `n` is the total
    /// number of requests ever recorded).
    pub fn latency_quantiles(&self) -> LatencyQuantiles {
        let (mut samples, seen) = {
            let r = self.latencies.lock().unwrap();
            (r.samples.clone(), r.seen)
        };
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        LatencyQuantiles {
            n: seen as usize,
            p50: percentile(&samples, 0.50),
            p99: percentile(&samples, 0.99),
            max: samples.last().copied().unwrap_or(0.0),
        }
    }

    /// Render the serving counters (and, when given, the model cache's
    /// hit statistics) as an aligned metric/value table.
    pub fn render(&self, cache: Option<&ModelCache>) -> Table {
        let lq = self.latency_quantiles();
        let mut t = Table::new("Serve metrics", &["metric", "value"]);
        let row = |t: &mut Table, k: &str, v: String| {
            t.row(&[k.to_string(), v]);
        };
        row(&mut t, "requests", self.requests.load(Ordering::Relaxed).to_string());
        row(&mut t, "responses", self.responses.load(Ordering::Relaxed).to_string());
        row(&mut t, "rejected", self.rejected.load(Ordering::Relaxed).to_string());
        row(&mut t, "batches", self.batches.load(Ordering::Relaxed).to_string());
        row(&mut t, "mean batch occupancy", format!("{:.2}", self.mean_occupancy()));
        row(&mut t, "p50 latency", format!("{:.3} ms", lq.p50 * 1e3));
        row(&mut t, "p99 latency", format!("{:.3} ms", lq.p99 * 1e3));
        if let Some(cache) = cache {
            let (h, m) = cache.stats();
            row(&mut t, "model-cache hits", h.to_string());
            row(&mut t, "model-cache misses", m.to_string());
            row(&mut t, "model-cache hit rate", format!("{:.1}%", cache.hit_rate() * 100.0));
            row(&mut t, "model-cache evictions", cache.evictions().to_string());
        }
        t
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        let lq = self.latency_quantiles();
        format!(
            "{} requests in {} batches (occupancy {:.2}); p50 {:.3} ms, p99 {:.3} ms, {} rejected",
            self.responses.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_occupancy(),
            lq.p50 * 1e3,
            lq.p99 * 1e3,
            self.rejected.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_and_quantiles() {
        let m = ServeMetrics::new();
        m.record_batch(4);
        m.record_batch(2);
        for secs in [0.001, 0.002, 0.003, 0.004, 0.005, 0.006] {
            m.record_latency(secs);
        }
        assert!((m.mean_occupancy() - 3.0).abs() < 1e-12);
        let lq = m.latency_quantiles();
        assert_eq!(lq.n, 6);
        assert!((lq.p50 - 0.0035).abs() < 1e-9);
        assert!(lq.p99 <= lq.max && lq.max == 0.006);
        let rendered = m.render(None).render();
        assert!(rendered.contains("mean batch occupancy"));
        assert!(rendered.contains("3.00"));
    }

    #[test]
    fn latency_reservoir_stays_bounded() {
        let m = ServeMetrics::new();
        let total = LATENCY_RESERVOIR + 500;
        for i in 0..total {
            m.record_latency(i as f64 * 1e-6);
        }
        let lq = m.latency_quantiles();
        // n counts every request; the stored samples stay capped.
        assert_eq!(lq.n, total);
        assert_eq!(m.latencies.lock().unwrap().samples.len(), LATENCY_RESERVOIR);
        assert!(lq.p50 > 0.0 && lq.p99 >= lq.p50 && lq.max >= lq.p99);
    }

    #[test]
    fn empty_metrics_render() {
        let m = ServeMetrics::new();
        assert_eq!(m.mean_occupancy(), 0.0);
        assert_eq!(m.latency_quantiles().n, 0);
        assert!(m.summary().contains("0 requests"));
    }
}
