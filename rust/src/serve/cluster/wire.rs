//! Length-prefixed binary wire protocol for multi-host serving.
//!
//! Every message on a cluster connection is one *frame*: a little-endian
//! `u32` byte length followed by a one-byte tag and the tag's body. The
//! conversation is strictly request/response — the router writes one
//! frame, the worker answers with exactly one — so the codec never needs
//! message IDs or reordering. Connections open with a handshake
//! ([`Frame::Hello`] ↔ [`Frame::HelloAck`]) carrying the protocol version
//! and the checkpoint identity hash from the placement plan, so a router
//! can never route traffic at a worker serving different bytes.
//!
//! Decoding follows the same discipline as the `.tenz` parser
//! (`io::tenz::scan_index`): every declared size is validated against the
//! bytes actually present *before* any allocation, truncation and bad
//! tags surface as typed [`WireError`]s (never panics), and the outer
//! length prefix is capped at [`MAX_FRAME_BYTES`] so a corrupt or hostile
//! peer cannot make the receiver allocate unboundedly.

use crate::tensor::Mat;
use std::io::{Read, Write};
use thiserror::Error;

/// Protocol version this build speaks. Bumped on any frame-layout change;
/// the handshake refuses mismatched peers up front. v2 added the
/// per-tenant admission rows to [`Frame::StatsOk`]; v3 added the
/// per-layer kernel summaries and span count (the fleet-wide obs
/// exposition).
pub const PROTOCOL_VERSION: u32 = 3;

/// Hard cap on one frame's payload (tag + body). A `Forward` carrying a
/// 4096-wide batch of 4096 f32 features is ~64 MiB; anything larger is a
/// corrupt length prefix, not traffic.
pub const MAX_FRAME_BYTES: usize = 1 << 26;

/// Typed wire failures. `Io` covers transport errors; everything else is
/// a protocol-level defect the corruption suite exercises.
#[derive(Debug, Error)]
pub enum WireError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("frame of {got} bytes exceeds the {max}-byte cap")]
    Oversized { got: u64, max: u64 },
    #[error("frame truncated at byte {at}: need {need} more, have {have}")]
    Truncated { at: usize, need: u64, have: u64 },
    #[error("unknown frame tag {0}")]
    BadTag(u8),
    #[error("frame string is not utf-8")]
    BadUtf8,
    #[error("malformed frame: {0}")]
    Malformed(String),
    #[error("peer speaks protocol {got}, this build speaks {want}")]
    VersionMismatch { got: u32, want: u32 },
    #[error("checkpoint hash mismatch: peer serves {got:016x}, plan says {want:016x}")]
    HashMismatch { got: u64, want: u64 },
    #[error("remote {code:?}: {message}")]
    Remote { code: ErrorCode, message: String },
    #[error("unexpected {0} frame in this protocol state")]
    Unexpected(&'static str),
}

/// Error categories a peer can answer with (the body of [`Frame::Error`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Handshake protocol-version disagreement.
    VersionMismatch,
    /// Handshake checkpoint-hash disagreement.
    HashMismatch,
    /// Request the worker refuses (wrong model, bad batch width, frame
    /// out of protocol order).
    BadRequest,
    /// The worker could not load its model assignment.
    ModelLoad,
    /// Execution failure inside the worker.
    Internal,
}

impl ErrorCode {
    fn tag(self) -> u16 {
        match self {
            ErrorCode::VersionMismatch => 1,
            ErrorCode::HashMismatch => 2,
            ErrorCode::BadRequest => 3,
            ErrorCode::ModelLoad => 4,
            ErrorCode::Internal => 5,
        }
    }

    fn from_tag(tag: u16) -> Result<Self, WireError> {
        Ok(match tag {
            1 => ErrorCode::VersionMismatch,
            2 => ErrorCode::HashMismatch,
            3 => ErrorCode::BadRequest,
            4 => ErrorCode::ModelLoad,
            5 => ErrorCode::Internal,
            other => return Err(WireError::Malformed(format!("unknown error code {other}"))),
        })
    }
}

/// Per-model latency statistics carried by [`Frame::StatsOk`] — the wire
/// form of [`LatencyQuantiles`](crate::serve::metrics::LatencyQuantiles),
/// keyed by checkpoint.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelStats {
    pub model: String,
    /// Requests ever recorded for this model.
    pub n: u64,
    pub p50: f64,
    pub p99: f64,
    pub max: f64,
}

/// Per-tenant admission statistics carried by [`Frame::StatsOk`] — the
/// wire form of [`TenantSnapshot`](crate::serve::metrics::TenantSnapshot).
/// `shed` folds in deadline sheds: on the wire a shed is a shed, however
/// late the server decided it.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantStats {
    pub tenant: String,
    pub offered: u64,
    pub admitted: u64,
    pub degraded: u64,
    pub shed: u64,
    pub p50: f64,
    pub p99: f64,
}

/// Per-layer GEMM telemetry carried by [`Frame::StatsOk`] since v3 — the
/// wire form of [`LayerStat`](crate::obs::layers::LayerStat), minus the
/// histogram buckets (the fleet view needs totals; the full histogram
/// stays a per-process exposition series).
#[derive(Debug, Clone, PartialEq)]
pub struct KernelStats {
    pub layer: String,
    pub calls: u64,
    pub rows: u64,
    pub flops: u64,
    pub total_secs: f64,
    pub max_secs: f64,
}

/// One protocol message. Request frames flow router → worker; `*Ok`,
/// `HelloAck` and `Error` flow back.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Connection opener: protocol version + checkpoint identity hash.
    Hello { version: u32, checkpoint_hash: u64 },
    /// Handshake acceptance, echoing the worker's own version and hash.
    HelloAck { version: u32, checkpoint_hash: u64 },
    /// Run one coalesced batch (N×D row-major) through the worker's
    /// layer assignment for `model`.
    Forward { model: String, batch: Mat<f32> },
    /// The batch's outputs, one row per input row, in order.
    ForwardOk { outputs: Mat<f32> },
    /// Liveness probe.
    Health,
    /// Liveness answer: models currently loaded, requests served.
    HealthOk { models: u32, requests: u64 },
    /// Ask for per-model latency statistics.
    Stats,
    /// Per-model latency statistics (sorted by model name), per-tenant
    /// admission rows (sorted by tenant name; empty when the worker
    /// serves no named tenants), per-layer kernel summaries (empty when
    /// the worker's obs collection is disabled), and the worker's span
    /// count.
    StatsOk {
        models: Vec<ModelStats>,
        tenants: Vec<TenantStats>,
        kernels: Vec<KernelStats>,
        spans: u64,
    },
    /// Typed failure answer to any request.
    Error { code: ErrorCode, message: String },
}

const TAG_HELLO: u8 = 1;
const TAG_HELLO_ACK: u8 = 2;
const TAG_FORWARD: u8 = 3;
const TAG_FORWARD_OK: u8 = 4;
const TAG_HEALTH: u8 = 5;
const TAG_HEALTH_OK: u8 = 6;
const TAG_STATS: u8 = 7;
const TAG_STATS_OK: u8 = 8;
const TAG_ERROR: u8 = 9;

/// Bounds-checked little-endian reader over one frame's bytes.
struct FrameReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> FrameReader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        FrameReader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated {
                at: self.pos,
                need: n as u64,
                have: self.remaining() as u64,
            });
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// u16-length-prefixed UTF-8 string.
    fn string(&mut self) -> Result<String, WireError> {
        let len = self.u16()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| WireError::BadUtf8)
    }

    /// `rows × cols` f32 matrix. The element count is validated against
    /// the bytes actually present before any allocation — a corrupt
    /// header cannot trigger an unbounded (or even oversized) `Vec`.
    fn mat(&mut self) -> Result<Mat<f32>, WireError> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let elems = (rows as u64)
            .checked_mul(cols as u64)
            .ok_or_else(|| WireError::Malformed("matrix element count overflows".into()))?;
        let nbytes = elems
            .checked_mul(4)
            .ok_or_else(|| WireError::Malformed("matrix byte count overflows".into()))?;
        if (self.remaining() as u64) < nbytes {
            return Err(WireError::Truncated {
                at: self.pos,
                need: nbytes,
                have: self.remaining() as u64,
            });
        }
        let raw = self.take(nbytes as usize)?;
        let data: Vec<f32> =
            raw.chunks_exact(4).map(|ch| f32::from_le_bytes(ch.try_into().unwrap())).collect();
        Ok(Mat::from_vec(rows, cols, data))
    }

    /// Every body must consume its frame exactly; trailing bytes mean a
    /// mangled length prefix or a mis-encoded frame.
    fn finish(self, what: &str) -> Result<(), WireError> {
        if self.remaining() != 0 {
            return Err(WireError::Malformed(format!(
                "{what} frame has {} trailing bytes",
                self.remaining()
            )));
        }
        Ok(())
    }
}

fn put_string(out: &mut Vec<u8>, s: &str) -> Result<(), WireError> {
    let len = u16::try_from(s.len())
        .map_err(|_| WireError::Malformed(format!("string of {} bytes exceeds u16", s.len())))?;
    out.extend_from_slice(&len.to_le_bytes());
    out.extend_from_slice(s.as_bytes());
    Ok(())
}

fn put_mat(out: &mut Vec<u8>, m: &Mat<f32>) -> Result<(), WireError> {
    let rows = u32::try_from(m.rows())
        .map_err(|_| WireError::Malformed("matrix rows exceed u32".into()))?;
    let cols = u32::try_from(m.cols())
        .map_err(|_| WireError::Malformed("matrix cols exceed u32".into()))?;
    out.extend_from_slice(&rows.to_le_bytes());
    out.extend_from_slice(&cols.to_le_bytes());
    out.reserve(m.len() * 4);
    for v in m.data() {
        out.extend_from_slice(&v.to_le_bytes());
    }
    Ok(())
}

impl Frame {
    /// Short name for diagnostics ([`WireError::Unexpected`]).
    pub fn name(&self) -> &'static str {
        match self {
            Frame::Hello { .. } => "Hello",
            Frame::HelloAck { .. } => "HelloAck",
            Frame::Forward { .. } => "Forward",
            Frame::ForwardOk { .. } => "ForwardOk",
            Frame::Health => "Health",
            Frame::HealthOk { .. } => "HealthOk",
            Frame::Stats => "Stats",
            Frame::StatsOk { .. } => "StatsOk",
            Frame::Error { .. } => "Error",
        }
    }

    /// Encode tag + body (everything after the length prefix).
    pub fn encode_body(&self) -> Result<Vec<u8>, WireError> {
        let mut out = Vec::new();
        match self {
            Frame::Hello { version, checkpoint_hash } => {
                out.push(TAG_HELLO);
                out.extend_from_slice(&version.to_le_bytes());
                out.extend_from_slice(&checkpoint_hash.to_le_bytes());
            }
            Frame::HelloAck { version, checkpoint_hash } => {
                out.push(TAG_HELLO_ACK);
                out.extend_from_slice(&version.to_le_bytes());
                out.extend_from_slice(&checkpoint_hash.to_le_bytes());
            }
            Frame::Forward { model, batch } => {
                out.push(TAG_FORWARD);
                put_string(&mut out, model)?;
                put_mat(&mut out, batch)?;
            }
            Frame::ForwardOk { outputs } => {
                out.push(TAG_FORWARD_OK);
                put_mat(&mut out, outputs)?;
            }
            Frame::Health => out.push(TAG_HEALTH),
            Frame::HealthOk { models, requests } => {
                out.push(TAG_HEALTH_OK);
                out.extend_from_slice(&models.to_le_bytes());
                out.extend_from_slice(&requests.to_le_bytes());
            }
            Frame::Stats => out.push(TAG_STATS),
            Frame::StatsOk { models, tenants, kernels, spans } => {
                out.push(TAG_STATS_OK);
                let count = u32::try_from(models.len())
                    .map_err(|_| WireError::Malformed("too many stats entries".into()))?;
                out.extend_from_slice(&count.to_le_bytes());
                for m in models {
                    put_string(&mut out, &m.model)?;
                    out.extend_from_slice(&m.n.to_le_bytes());
                    out.extend_from_slice(&m.p50.to_le_bytes());
                    out.extend_from_slice(&m.p99.to_le_bytes());
                    out.extend_from_slice(&m.max.to_le_bytes());
                }
                let count = u32::try_from(tenants.len())
                    .map_err(|_| WireError::Malformed("too many tenant entries".into()))?;
                out.extend_from_slice(&count.to_le_bytes());
                for t in tenants {
                    put_string(&mut out, &t.tenant)?;
                    out.extend_from_slice(&t.offered.to_le_bytes());
                    out.extend_from_slice(&t.admitted.to_le_bytes());
                    out.extend_from_slice(&t.degraded.to_le_bytes());
                    out.extend_from_slice(&t.shed.to_le_bytes());
                    out.extend_from_slice(&t.p50.to_le_bytes());
                    out.extend_from_slice(&t.p99.to_le_bytes());
                }
                let count = u32::try_from(kernels.len())
                    .map_err(|_| WireError::Malformed("too many kernel entries".into()))?;
                out.extend_from_slice(&count.to_le_bytes());
                for k in kernels {
                    put_string(&mut out, &k.layer)?;
                    out.extend_from_slice(&k.calls.to_le_bytes());
                    out.extend_from_slice(&k.rows.to_le_bytes());
                    out.extend_from_slice(&k.flops.to_le_bytes());
                    out.extend_from_slice(&k.total_secs.to_le_bytes());
                    out.extend_from_slice(&k.max_secs.to_le_bytes());
                }
                out.extend_from_slice(&spans.to_le_bytes());
            }
            Frame::Error { code, message } => {
                out.push(TAG_ERROR);
                out.extend_from_slice(&code.tag().to_le_bytes());
                put_string(&mut out, message)?;
            }
        }
        if out.len() > MAX_FRAME_BYTES {
            return Err(WireError::Oversized {
                got: out.len() as u64,
                max: MAX_FRAME_BYTES as u64,
            });
        }
        Ok(out)
    }

    /// Decode tag + body. Never panics and never allocates more than the
    /// buffer it is handed; all failures are typed [`WireError`]s.
    pub fn decode_body(buf: &[u8]) -> Result<Frame, WireError> {
        let mut r = FrameReader::new(buf);
        let tag = r.u8()?;
        let frame = match tag {
            TAG_HELLO => {
                Frame::Hello { version: r.u32()?, checkpoint_hash: r.u64()? }
            }
            TAG_HELLO_ACK => {
                Frame::HelloAck { version: r.u32()?, checkpoint_hash: r.u64()? }
            }
            TAG_FORWARD => {
                let model = r.string()?;
                let batch = r.mat()?;
                Frame::Forward { model, batch }
            }
            TAG_FORWARD_OK => Frame::ForwardOk { outputs: r.mat()? },
            TAG_HEALTH => Frame::Health,
            TAG_HEALTH_OK => Frame::HealthOk { models: r.u32()?, requests: r.u64()? },
            TAG_STATS => Frame::Stats,
            TAG_STATS_OK => {
                let count = r.u32()? as usize;
                // Each entry is ≥ 34 bytes; refuse counts the remaining
                // bytes cannot possibly hold before reserving anything.
                if count > r.remaining() / 34 {
                    return Err(WireError::Malformed(format!(
                        "stats count {count} exceeds frame capacity"
                    )));
                }
                let mut models = Vec::with_capacity(count);
                for _ in 0..count {
                    models.push(ModelStats {
                        model: r.string()?,
                        n: r.u64()?,
                        p50: r.f64()?,
                        p99: r.f64()?,
                        max: r.f64()?,
                    });
                }
                let count = r.u32()? as usize;
                // Each tenant row is ≥ 50 bytes (2-byte string prefix +
                // 4×u64 + 2×f64); same pre-allocation guard as above.
                if count > r.remaining() / 50 {
                    return Err(WireError::Malformed(format!(
                        "tenant stats count {count} exceeds frame capacity"
                    )));
                }
                let mut tenants = Vec::with_capacity(count);
                for _ in 0..count {
                    tenants.push(TenantStats {
                        tenant: r.string()?,
                        offered: r.u64()?,
                        admitted: r.u64()?,
                        degraded: r.u64()?,
                        shed: r.u64()?,
                        p50: r.f64()?,
                        p99: r.f64()?,
                    });
                }
                let count = r.u32()? as usize;
                // Each kernel row is ≥ 42 bytes (2-byte string prefix +
                // 3×u64 + 2×f64); same pre-allocation guard as above.
                if count > r.remaining() / 42 {
                    return Err(WireError::Malformed(format!(
                        "kernel stats count {count} exceeds frame capacity"
                    )));
                }
                let mut kernels = Vec::with_capacity(count);
                for _ in 0..count {
                    kernels.push(KernelStats {
                        layer: r.string()?,
                        calls: r.u64()?,
                        rows: r.u64()?,
                        flops: r.u64()?,
                        total_secs: r.f64()?,
                        max_secs: r.f64()?,
                    });
                }
                let spans = r.u64()?;
                Frame::StatsOk { models, tenants, kernels, spans }
            }
            TAG_ERROR => {
                let code = ErrorCode::from_tag(r.u16()?)?;
                Frame::Error { code, message: r.string()? }
            }
            other => return Err(WireError::BadTag(other)),
        };
        r.finish(frame.name())?;
        Ok(frame)
    }
}

/// Write one frame: u32 length prefix, then tag + body.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<(), WireError> {
    let body = frame.encode_body()?;
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(&body)?;
    w.flush()?;
    Ok(())
}

/// Read one frame. The length prefix is validated against
/// [`MAX_FRAME_BYTES`] *before* the body buffer is allocated.
pub fn read_frame(r: &mut impl Read) -> Result<Frame, WireError> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(WireError::Oversized { got: len as u64, max: MAX_FRAME_BYTES as u64 });
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Frame::decode_body(&body)
}

/// One request/response exchange on an established connection.
pub fn call(stream: &mut (impl Read + Write), request: &Frame) -> Result<Frame, WireError> {
    write_frame(stream, request)?;
    read_frame(stream)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_frames() -> Vec<Frame> {
        vec![
            Frame::Hello { version: 1, checkpoint_hash: 0xdead_beef },
            Frame::HelloAck { version: 7, checkpoint_hash: u64::MAX },
            Frame::Forward {
                model: "ckpt/model.toml".into(),
                batch: Mat::from_fn(3, 5, |r, c| (r * 5 + c) as f32 * 0.5 - 3.0),
            },
            Frame::ForwardOk { outputs: Mat::from_fn(3, 2, |r, c| (r + c) as f32) },
            Frame::Health,
            Frame::HealthOk { models: 2, requests: 12345 },
            Frame::Stats,
            Frame::StatsOk {
                models: vec![
                    ModelStats { model: "a.tenz".into(), n: 9, p50: 0.001, p99: 0.005, max: 0.9 },
                    ModelStats { model: "b.toml".into(), n: 0, p50: 0.0, p99: 0.0, max: 0.0 },
                ],
                tenants: vec![
                    TenantStats {
                        tenant: "gold".into(),
                        offered: 120,
                        admitted: 100,
                        degraded: 15,
                        shed: 5,
                        p50: 0.002,
                        p99: 0.04,
                    },
                    TenantStats {
                        tenant: "free".into(),
                        offered: 0,
                        admitted: 0,
                        degraded: 0,
                        shed: 0,
                        p50: 0.0,
                        p99: 0.0,
                    },
                ],
                kernels: vec![
                    KernelStats {
                        layer: "layers.0".into(),
                        calls: 17,
                        rows: 544,
                        flops: 8_912_896,
                        total_secs: 0.021,
                        max_secs: 0.004,
                    },
                    KernelStats {
                        layer: "head".into(),
                        calls: 17,
                        rows: 544,
                        flops: 1_114_112,
                        total_secs: 0.003,
                        max_secs: 0.001,
                    },
                ],
                spans: 99,
            },
            Frame::StatsOk { models: vec![], tenants: vec![], kernels: vec![], spans: 0 },
            Frame::Error { code: ErrorCode::ModelLoad, message: "no such shard".into() },
        ]
    }

    #[test]
    fn every_frame_roundtrips() {
        for f in sample_frames() {
            let body = f.encode_body().unwrap();
            let back = Frame::decode_body(&body).unwrap();
            assert_eq!(back, f, "frame {:?}", f.name());
        }
    }

    #[test]
    fn stream_roundtrip_back_to_back() {
        let mut buf = Vec::new();
        for f in sample_frames() {
            write_frame(&mut buf, &f).unwrap();
        }
        let mut cursor = std::io::Cursor::new(buf);
        for f in sample_frames() {
            assert_eq!(read_frame(&mut cursor).unwrap(), f);
        }
        // The stream is exactly consumed.
        assert!(matches!(read_frame(&mut cursor), Err(WireError::Io(_))));
    }

    #[test]
    fn oversized_length_prefix_rejected_before_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        buf.extend_from_slice(&[0; 16]);
        let err = read_frame(&mut std::io::Cursor::new(buf)).unwrap_err();
        assert!(matches!(err, WireError::Oversized { .. }), "{err}");
    }

    #[test]
    fn huge_declared_matrix_is_truncation_not_allocation() {
        // Forward frame declaring u32::MAX × u32::MAX rows/cols with a
        // tiny actual payload must fail cleanly before any reserve.
        let mut body = vec![TAG_FORWARD];
        put_string(&mut body, "m").unwrap();
        body.extend_from_slice(&u32::MAX.to_le_bytes());
        body.extend_from_slice(&u32::MAX.to_le_bytes());
        body.extend_from_slice(&[0u8; 8]);
        let err = Frame::decode_body(&body).unwrap_err();
        assert!(
            matches!(err, WireError::Truncated { .. } | WireError::Malformed(_)),
            "{err}"
        );
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut body = Frame::Health.encode_body().unwrap();
        body.push(0);
        assert!(matches!(Frame::decode_body(&body), Err(WireError::Malformed(_))));
    }

    #[test]
    fn bad_tag_and_bad_code_rejected() {
        assert!(matches!(Frame::decode_body(&[200]), Err(WireError::BadTag(200))));
        assert!(matches!(Frame::decode_body(&[]), Err(WireError::Truncated { .. })));
        let mut body = vec![TAG_ERROR];
        body.extend_from_slice(&99u16.to_le_bytes());
        body.extend_from_slice(&0u16.to_le_bytes());
        assert!(matches!(Frame::decode_body(&body), Err(WireError::Malformed(_))));
    }

    #[test]
    fn huge_declared_stats_counts_rejected_before_allocation() {
        // Model count far past what the frame can hold.
        let mut body = vec![TAG_STATS_OK];
        body.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = Frame::decode_body(&body).unwrap_err();
        assert!(matches!(err, WireError::Malformed(_)), "{err}");
        // Zero models, then an absurd tenant count.
        let mut body = vec![TAG_STATS_OK];
        body.extend_from_slice(&0u32.to_le_bytes());
        body.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = Frame::decode_body(&body).unwrap_err();
        assert!(matches!(err, WireError::Malformed(_)), "{err}");
        // Zero models and tenants, then an absurd kernel count.
        let mut body = vec![TAG_STATS_OK];
        body.extend_from_slice(&0u32.to_le_bytes());
        body.extend_from_slice(&0u32.to_le_bytes());
        body.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = Frame::decode_body(&body).unwrap_err();
        assert!(matches!(err, WireError::Malformed(_)), "{err}");
    }

    #[test]
    fn non_utf8_string_rejected() {
        let mut body = vec![TAG_FORWARD];
        body.extend_from_slice(&2u16.to_le_bytes());
        body.extend_from_slice(&[0xff, 0xfe]);
        body.extend_from_slice(&0u32.to_le_bytes());
        body.extend_from_slice(&0u32.to_le_bytes());
        assert!(matches!(Frame::decode_body(&body), Err(WireError::BadUtf8)));
    }
}
