//! A cluster worker: one process serving its placement-plan assignment
//! over TCP.
//!
//! `rsic worker --listen ADDR --plan P` runs one of these. The worker
//! reads the shared placement plan, takes assignment `--index`, and
//! serves [`Forward`](Frame::Forward) requests over the wire protocol:
//!
//! * The model loads **lazily on first traffic**, through
//!   [`CheckpointSource`] — on a sharded checkpoint a partitioned worker
//!   materializes only its assigned layers, so only their shards are
//!   ever opened (the `ShardedReader` laziness the placement planner
//!   counts on).
//! * Batches execute on the worker's own [`WorkerPool`] — the same
//!   engine single-process serving uses, which is what makes routed
//!   outputs bit-identical to local ones.
//! * Every connection starts with a handshake checking the protocol
//!   version and the plan's checkpoint identity hash; a router pointed
//!   at a worker serving different bytes is refused with a typed error
//!   frame before any traffic flows.
//!
//! [`Worker::spawn`] runs the same accept loop on a background thread
//! with an ephemeral port — the loopback form the cluster tests and the
//! CI smoke step drive.

use super::placement::PlacementPlan;
use super::wire::{
    read_frame, write_frame, ErrorCode, Frame, KernelStats, ModelStats, TenantStats,
    PROTOCOL_VERSION,
};
use crate::coordinator::pool::WorkerPool;
use crate::io::checkpoint::CheckpointSource;
use crate::serve::kernel::ModelKernels;
use crate::serve::metrics::ServeMetrics;
use anyhow::{Context, Result};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Worker construction options (the `rsic worker` CLI flags).
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    /// Address to bind (`host:port`; port 0 binds ephemerally).
    pub listen: String,
    /// The shared placement plan.
    pub plan: PlacementPlan,
    /// Which of the plan's assignments this worker serves.
    pub index: usize,
    /// Threads in the worker's forward-pass pool.
    pub threads: usize,
    /// Bounded job-queue depth of the pool.
    pub queue_depth: usize,
    /// Run the checkpoint integrity pass (`verify_hashes` on sharded
    /// checkpoints, a full structural read on single files) at model
    /// load, before answering any traffic with it.
    pub verify: bool,
}

impl WorkerConfig {
    pub fn new(listen: impl Into<String>, plan: PlacementPlan, index: usize) -> Self {
        WorkerConfig {
            listen: listen.into(),
            plan,
            index,
            threads: crate::util::default_threads(),
            queue_depth: 16,
            verify: false,
        }
    }
}

/// Shared state of one worker process.
struct WorkerState {
    plan: PlacementPlan,
    index: usize,
    verify: bool,
    pool: WorkerPool,
    metrics: ServeMetrics,
    /// Kernels for the plan's checkpoint, loaded on first Forward. The
    /// load error (if any) is not cached: a worker started before its
    /// checkpoint finished writing recovers on a later request.
    model: Mutex<Option<Arc<ModelKernels>>>,
}

impl WorkerState {
    /// This worker's layer assignment slice of the plan.
    fn assignment(&self) -> &super::placement::WorkerAssignment {
        &self.plan.workers[self.index]
    }

    /// Load (or fetch) the kernels for this worker's assignment.
    fn model(&self) -> Result<Arc<ModelKernels>, String> {
        let mut guard = crate::util::lock_recover(&self.model);
        if let Some(m) = &*guard {
            return Ok(m.clone());
        }
        let src = CheckpointSource::open(&self.plan.checkpoint)
            .map_err(|e| format!("opening {}: {e}", self.plan.checkpoint))?;
        if self.verify {
            src.verify().map_err(|e| format!("verifying {}: {e}", self.plan.checkpoint))?;
        }
        // A partition plan that skips, duplicates or reorders layers
        // would serve wrong outputs whenever stage widths line up —
        // refuse it here (typed ModelLoad error over the wire) instead.
        self.plan.validate_layers(&src).map_err(|e| format!("{e:#}"))?;
        let assignment = self.assignment();
        let loaded = if assignment.layers.is_empty() {
            ModelKernels::load(&src)
        } else {
            let final_stage = self.index + 1 == self.plan.workers.len();
            ModelKernels::load_subset(&src, &assignment.layers, final_stage)
        }
        .map_err(|e| format!("loading {}: {e:#}", self.plan.checkpoint))?;
        let m = Arc::new(loaded);
        *guard = Some(m.clone());
        Ok(m)
    }

    fn models_loaded(&self) -> u32 {
        u32::from(crate::util::lock_recover(&self.model).is_some())
    }
}

/// Handle to an in-process worker (loopback testing, the routed bench).
/// Dropping it shuts the worker down and joins every thread.
pub struct WorkerHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    state: Arc<WorkerState>,
}

impl WorkerHandle {
    /// The address the worker actually bound (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The worker's serving metrics (assertion surface for tests).
    pub fn metrics(&self) -> &ServeMetrics {
        &self.state.metrics
    }

    /// Stop accepting, close connections, join threads. Idempotent;
    /// also runs on drop.
    pub fn shutdown(&mut self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        if crate::obs::enabled() {
            crate::obs::recorder::record(
                crate::obs::recorder::EventKind::WorkerDown,
                format!("addr={} reason=shutdown", self.addr),
            );
        }
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(200));
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The worker entry points.
pub struct Worker;

impl Worker {
    /// Bind `config.listen` and serve on a background thread. Returns
    /// once the socket is listening, so the caller can hand the real
    /// address (ephemeral ports resolved) to a router.
    pub fn spawn(config: WorkerConfig) -> Result<WorkerHandle> {
        anyhow::ensure!(
            config.index < config.plan.workers.len(),
            "worker index {} out of range for a {}-worker plan",
            config.index,
            config.plan.workers.len()
        );
        let listener = TcpListener::bind(&config.listen)
            .with_context(|| format!("binding worker listener on {}", config.listen))?;
        let addr = listener.local_addr()?;
        let state = Arc::new(WorkerState {
            index: config.index,
            verify: config.verify,
            pool: WorkerPool::new(config.threads, config.queue_depth.max(1)),
            metrics: ServeMetrics::new(),
            model: Mutex::new(None),
            plan: config.plan,
        });
        let shutdown = Arc::new(AtomicBool::new(false));
        let loop_state = state.clone();
        let loop_shutdown = shutdown.clone();
        let accept_thread = std::thread::Builder::new()
            .name(format!("rsic-cluster-worker-{}", config.index))
            .spawn(move || accept_loop(listener, loop_state, loop_shutdown))
            .context("spawning worker accept thread")?;
        Ok(WorkerHandle { addr, shutdown, accept_thread: Some(accept_thread), state })
    }

    /// Blocking form for the `rsic worker` CLI: serve until the process
    /// is killed.
    pub fn run(config: WorkerConfig) -> Result<()> {
        let handle = Self::spawn(config)?;
        log::info!("worker listening on {}", handle.addr());
        println!("worker listening on {}", handle.addr());
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
}

fn accept_loop(listener: TcpListener, state: Arc<WorkerState>, shutdown: Arc<AtomicBool>) {
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !shutdown.load(Ordering::SeqCst) {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => {
                // Persistent accept errors (fd exhaustion, say) must not
                // busy-spin a core on a long-lived worker.
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
        };
        if shutdown.load(Ordering::SeqCst) {
            break; // the wake-up connection from WorkerHandle::shutdown
        }
        let conn_state = state.clone();
        let conn_shutdown = shutdown.clone();
        if let Ok(t) = std::thread::Builder::new()
            .name("rsic-cluster-conn".into())
            .spawn(move || serve_conn(stream, conn_state, conn_shutdown))
        {
            conns.push(t);
        }
        // Reap finished connection threads so a long-lived worker does
        // not accumulate handles.
        conns.retain(|t| !t.is_finished());
    }
    for t in conns {
        let _ = t.join();
    }
}

/// How often an idle connection re-checks the shutdown flag.
const IDLE_POLL: Duration = Duration::from_millis(200);
/// Full I/O budget for one frame once its first byte has arrived (and
/// for writes). A peer stalling longer mid-frame is treated as dead.
const FRAME_TIMEOUT: Duration = Duration::from_secs(30);

/// One connection: handshake, then a request/response loop until EOF,
/// shutdown, or an unrecoverable protocol error.
fn serve_conn(mut stream: TcpStream, state: Arc<WorkerState>, shutdown: Arc<AtomicBool>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_write_timeout(Some(FRAME_TIMEOUT));
    let mut greeted = false;
    while !shutdown.load(Ordering::SeqCst) {
        // Idle-wait for the next frame's first byte with a short poll so
        // shutdown is noticed promptly. `peek` consumes nothing, so a
        // timeout here can never desynchronize the stream — unlike a
        // timeout inside `read_frame`, whose `read_exact` may already
        // have consumed part of a frame.
        let _ = stream.set_read_timeout(Some(IDLE_POLL));
        let mut probe = [0u8; 1];
        match stream.peek(&mut probe) {
            Ok(0) => return, // EOF
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => return,
        }
        // A frame has started: give it the full I/O budget. A mid-frame
        // stall past it closes the connection (the stream position would
        // be unrecoverable anyway) — never a silent resync.
        let _ = stream.set_read_timeout(Some(FRAME_TIMEOUT));
        let frame = match read_frame(&mut stream) {
            Ok(f) => f,
            Err(super::wire::WireError::Io(_)) => return, // peer gone or stalled
            Err(e) => {
                // Protocol corruption: answer with a typed error and
                // close — the stream position is no longer trustworthy.
                let _ = write_frame(
                    &mut stream,
                    &Frame::Error { code: ErrorCode::BadRequest, message: e.to_string() },
                );
                return;
            }
        };
        let reply = match frame {
            Frame::Hello { version, checkpoint_hash } => {
                if version != PROTOCOL_VERSION {
                    let _ = write_frame(
                        &mut stream,
                        &Frame::Error {
                            code: ErrorCode::VersionMismatch,
                            message: format!(
                                "peer speaks protocol {version}, worker speaks {PROTOCOL_VERSION}"
                            ),
                        },
                    );
                    return;
                }
                if checkpoint_hash != state.plan.checkpoint_hash {
                    let _ = write_frame(
                        &mut stream,
                        &Frame::Error {
                            code: ErrorCode::HashMismatch,
                            message: format!(
                                "peer expects checkpoint {checkpoint_hash:016x}, worker serves {:016x}",
                                state.plan.checkpoint_hash
                            ),
                        },
                    );
                    return;
                }
                greeted = true;
                Frame::HelloAck {
                    version: PROTOCOL_VERSION,
                    checkpoint_hash: state.plan.checkpoint_hash,
                }
            }
            _ if !greeted => Frame::Error {
                code: ErrorCode::BadRequest,
                message: "connection must open with Hello".into(),
            },
            Frame::Forward { model, batch } => handle_forward(&state, &model, batch),
            Frame::Health => Frame::HealthOk {
                models: state.models_loaded(),
                requests: state.metrics.responses.load(Ordering::Relaxed),
            },
            Frame::Stats => Frame::StatsOk {
                models: state
                    .metrics
                    .model_quantiles()
                    .into_iter()
                    .map(|(model, lq)| ModelStats {
                        model,
                        n: lq.n as u64,
                        p50: lq.p50,
                        p99: lq.p99,
                        max: lq.max,
                    })
                    .collect(),
                tenants: state
                    .metrics
                    .tenant_snapshots()
                    .into_iter()
                    .map(|t| TenantStats {
                        tenant: t.tenant,
                        offered: t.counters.offered,
                        admitted: t.counters.admitted,
                        degraded: t.counters.degraded,
                        // On the wire a shed is a shed, however late the
                        // server decided it.
                        shed: t.counters.shed + t.counters.deadline_shed,
                        p50: t.latency.p50,
                        p99: t.latency.p99,
                    })
                    .collect(),
                // Per-layer kernel timings and the span count ride the
                // same Stats round trip so the router scrapes a whole
                // worker in one RTT.
                kernels: crate::obs::layers::snapshot()
                    .into_iter()
                    .map(|(layer, s)| KernelStats {
                        layer,
                        calls: s.calls,
                        rows: s.rows,
                        flops: s.flops,
                        total_secs: s.total_secs,
                        max_secs: s.max_secs,
                    })
                    .collect(),
                spans: crate::obs::span::recorded_total(),
            },
            other => Frame::Error {
                code: ErrorCode::BadRequest,
                message: format!("unexpected {} frame on a worker", other.name()),
            },
        };
        if write_frame(&mut stream, &reply).is_err() {
            return;
        }
    }
}

/// Execute one routed batch on the worker's pool.
fn handle_forward(state: &Arc<WorkerState>, model: &str, batch: crate::tensor::Mat<f32>) -> Frame {
    if model != state.plan.checkpoint {
        return Frame::Error {
            code: ErrorCode::BadRequest,
            message: format!(
                "worker serves {:?}, request names {model:?}",
                state.plan.checkpoint
            ),
        };
    }
    let kernels = match state.model() {
        Ok(k) => k,
        Err(e) => return Frame::Error { code: ErrorCode::ModelLoad, message: e },
    };
    if batch.cols() != kernels.input_dim() {
        return Frame::Error {
            code: ErrorCode::BadRequest,
            message: format!(
                "batch is {} features wide, stage expects {}",
                batch.cols(),
                kernels.input_dim()
            ),
        };
    }
    let started = Instant::now();
    let rows = batch.rows();
    let job_kernels = kernels.clone();
    let result = state.pool.submit_handle(move || job_kernels.forward(&batch)).wait();
    match result {
        Ok(outputs) => {
            state.metrics.record_batch(rows);
            // One latency sample per row, recorded in one lock pass —
            // every request in the batch waited the same wall time.
            state.metrics.record_latency_n(model, started.elapsed().as_secs_f64(), rows);
            if crate::obs::enabled() {
                use crate::obs::span::ArgVal;
                crate::obs::span::record(
                    "worker_forward",
                    started,
                    vec![
                        ("model", ArgVal::Str(model.to_string())),
                        ("rows", ArgVal::U64(rows as u64)),
                    ],
                );
            }
            Frame::ForwardOk { outputs }
        }
        Err(e) => Frame::Error { code: ErrorCode::Internal, message: e },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::checkpoint::{store_weight, StoredWeight};
    use crate::io::tenz::TensorFile;
    use crate::rng::GaussianSource;
    use crate::serve::cluster::placement::{
        checkpoint_identity_hash, PlacementMode, PlacementPlan,
    };
    use crate::serve::cluster::wire::call;
    use crate::tensor::init::gaussian;
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("cluster_worker_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn spawn_replica_worker(tag: &str) -> (WorkerHandle, PlacementPlan, PathBuf) {
        let dir = tmp_dir(tag);
        let path = dir.join("m.tenz");
        let mut g = GaussianSource::new(5);
        let mut tf = TensorFile::new();
        store_weight(&mut tf, "head", &StoredWeight::Dense(gaussian(3, 4, 1.0, &mut g)));
        tf.write(&path).unwrap();
        let hash = checkpoint_identity_hash(&path).unwrap();
        let plan = PlacementPlan::build(
            &TensorFile::read(&path).unwrap(),
            path.to_str().unwrap(),
            hash,
            PlacementMode::Replica,
            &["".to_string()],
        )
        .unwrap();
        let handle = Worker::spawn(WorkerConfig::new("127.0.0.1:0", plan.clone(), 0)).unwrap();
        (handle, plan, dir)
    }

    fn handshake(stream: &mut TcpStream, plan: &PlacementPlan) {
        let hello =
            Frame::Hello { version: PROTOCOL_VERSION, checkpoint_hash: plan.checkpoint_hash };
        match call(stream, &hello).unwrap() {
            Frame::HelloAck { version, checkpoint_hash } => {
                assert_eq!(version, PROTOCOL_VERSION);
                assert_eq!(checkpoint_hash, plan.checkpoint_hash);
            }
            other => panic!("expected HelloAck, got {other:?}"),
        }
    }

    #[test]
    fn worker_answers_health_and_forward() {
        let (handle, plan, dir) = spawn_replica_worker("basic");
        let mut stream = TcpStream::connect(handle.addr()).unwrap();
        handshake(&mut stream, &plan);
        // Model loads lazily: nothing is resident before the first Forward.
        match call(&mut stream, &Frame::Health).unwrap() {
            Frame::HealthOk { models, requests } => {
                assert_eq!(models, 0);
                assert_eq!(requests, 0);
            }
            other => panic!("{other:?}"),
        }
        let batch = crate::tensor::Mat::from_fn(2, 4, |r, c| (r * 4 + c) as f32);
        let outputs = match call(
            &mut stream,
            &Frame::Forward { model: plan.checkpoint.clone(), batch },
        )
        .unwrap()
        {
            Frame::ForwardOk { outputs } => outputs,
            other => panic!("{other:?}"),
        };
        assert_eq!(outputs.shape(), (2, 3));
        match call(&mut stream, &Frame::Health).unwrap() {
            Frame::HealthOk { models, requests } => {
                assert_eq!(models, 1);
                assert_eq!(requests, 2);
            }
            other => panic!("{other:?}"),
        }
        match call(&mut stream, &Frame::Stats).unwrap() {
            Frame::StatsOk { models, tenants, .. } => {
                assert_eq!(models.len(), 1);
                assert_eq!(models[0].model, plan.checkpoint);
                assert_eq!(models[0].n, 2);
                // A forward-only worker tracks no named tenants.
                assert!(tenants.is_empty(), "{tenants:?}");
            }
            other => panic!("{other:?}"),
        }
        drop(handle);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn handshake_rejects_version_and_hash_mismatch() {
        let (handle, plan, dir) = spawn_replica_worker("mismatch");
        let mut stream = TcpStream::connect(handle.addr()).unwrap();
        let bad_version =
            Frame::Hello { version: 99, checkpoint_hash: plan.checkpoint_hash };
        match call(&mut stream, &bad_version).unwrap() {
            Frame::Error { code, .. } => assert_eq!(code, ErrorCode::VersionMismatch),
            other => panic!("{other:?}"),
        }
        let mut stream = TcpStream::connect(handle.addr()).unwrap();
        let bad_hash = Frame::Hello {
            version: PROTOCOL_VERSION,
            checkpoint_hash: plan.checkpoint_hash ^ 1,
        };
        match call(&mut stream, &bad_hash).unwrap() {
            Frame::Error { code, .. } => assert_eq!(code, ErrorCode::HashMismatch),
            other => panic!("{other:?}"),
        }
        // Skipping the handshake entirely is refused too.
        let mut stream = TcpStream::connect(handle.addr()).unwrap();
        match call(&mut stream, &Frame::Health).unwrap() {
            Frame::Error { code, message } => {
                assert_eq!(code, ErrorCode::BadRequest);
                assert!(message.contains("Hello"), "{message}");
            }
            other => panic!("{other:?}"),
        }
        drop(handle);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bad_requests_get_typed_errors_not_disconnects() {
        let (handle, plan, dir) = spawn_replica_worker("badreq");
        let mut stream = TcpStream::connect(handle.addr()).unwrap();
        handshake(&mut stream, &plan);
        // Wrong model name.
        let batch = crate::tensor::Mat::zeros(1, 4);
        match call(&mut stream, &Frame::Forward { model: "other".into(), batch }).unwrap() {
            Frame::Error { code, .. } => assert_eq!(code, ErrorCode::BadRequest),
            other => panic!("{other:?}"),
        }
        // Wrong batch width — and the connection survives both errors.
        let batch = crate::tensor::Mat::zeros(1, 9);
        match call(
            &mut stream,
            &Frame::Forward { model: plan.checkpoint.clone(), batch },
        )
        .unwrap()
        {
            Frame::Error { code, message } => {
                assert_eq!(code, ErrorCode::BadRequest);
                assert!(message.contains("9 features"), "{message}");
            }
            other => panic!("{other:?}"),
        }
        let good = crate::tensor::Mat::zeros(1, 4);
        assert!(matches!(
            call(&mut stream, &Frame::Forward { model: plan.checkpoint.clone(), batch: good })
                .unwrap(),
            Frame::ForwardOk { .. }
        ));
        drop(handle);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
