//! `cluster` — multi-host serving of sharded checkpoints.
//!
//! PR 4's sharded checkpoints removed the single-file ceiling, but every
//! byte still flowed through one process. This subsystem turns `rsic`
//! from a process into a fleet over plain `std::net` TCP (loopback-
//! testable, no new dependencies):
//!
//! * [`wire`] — the length-prefixed binary protocol: version+hash
//!   handshake, `Forward`/`Health`/`Stats` requests, typed error frames,
//!   with a corruption-hardened codec (every declared size validated
//!   before allocation).
//! * [`placement`] — the planner: reads a checkpoint's shard manifest +
//!   per-layer metadata and partitions layers across N workers by a cost
//!   model over stored bytes *and* MACs (dense `C·D` vs factored
//!   `k(C+D)` — the paper's accounting tells the planner which layers
//!   are compute-cheap), emitting a TOML placement plan.
//! * [`worker`] — `rsic worker --listen ADDR --plan P`: a process that
//!   lazily opens only its assigned shards and runs the existing
//!   `serve::kernel`s on its own `WorkerPool`.
//! * [`router`] — the front end the micro-batcher drains into: whole
//!   batches replica-style, or stage-to-stage for partitioned models,
//!   with health-checked connections, bounded retry, and failover to
//!   local in-process execution when a worker dies mid-request.
//!
//! Invariants (tested in `tests/cluster.rs`):
//!
//! * Routed outputs are **bit-identical** to single-process serving —
//!   the distributed pass preserves the exact numerics the paper's
//!   softmax-perturbation theorem bounds, so every served-equivalence
//!   guarantee carries over unchanged.
//! * A worker dying mid-traffic degrades to local execution with zero
//!   client-visible errors.
//! * Corrupt frames yield typed errors, never panics or unbounded
//!   allocations.
//! * The planner's heaviest worker stays within 1.5× of the mean load.

pub mod placement;
pub mod router;
pub mod wire;
pub mod worker;

pub use placement::{
    checkpoint_identity_hash, checkpoint_identity_hash_of, layer_costs, LayerCost,
    PlacementMode, PlacementPlan, WorkerAssignment,
};
pub use router::{RoutedExecutor, Router, RouterConfig, WorkerObs};
pub use wire::{
    ErrorCode, Frame, KernelStats, ModelStats, TenantStats, WireError, MAX_FRAME_BYTES,
    PROTOCOL_VERSION,
};
pub use worker::{Worker, WorkerConfig, WorkerHandle};
