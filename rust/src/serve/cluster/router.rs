//! The routing front end: where micro-batches leave the process.
//!
//! A [`Router`] owns one health-tracked connection per placement-plan
//! worker and moves whole coalesced batches over the wire:
//!
//! * **replica** plans — each batch goes to one worker, chosen
//!   round-robin; a failed worker is skipped (bounded retry across the
//!   remaining replicas) and marked down until a later call revives it.
//! * **partition** plans — the batch flows stage-to-stage: worker 0's
//!   outputs become worker 1's inputs, exactly the layer chain the
//!   single-process pass runs, so the routed result is bit-identical.
//!
//! The [`RoutedExecutor`] is the glue into the existing serving path:
//! the micro-batcher drains into it like any [`BatchExecutor`], and when
//! the fleet cannot answer (workers dead mid-request, wire corruption,
//! handshake refusal) it **fails over to local in-process execution** —
//! the kernels are already resident from the model cache — so a worker
//! dying mid-traffic degrades to single-host serving with zero
//! client-visible errors.

use super::placement::{PlacementMode, PlacementPlan};
use super::wire::{self, Frame, WireError, PROTOCOL_VERSION};
use crate::serve::batcher::{BatchExecutor, LocalExecutor};
use crate::serve::metrics::ServeMetrics;
use crate::tensor::Mat;
use std::net::{TcpStream, ToSocketAddrs};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Router tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// TCP connect timeout per worker.
    pub connect_timeout: Duration,
    /// Read/write timeout on established connections.
    pub io_timeout: Duration,
    /// How long a replica marked down is skipped before the scheduler
    /// risks a batch on it again. Small enough that a restarted worker
    /// rejoins within a second of traffic; large enough that a dead one
    /// costs at most one connect timeout per interval, not per batch.
    pub reprobe_after: Duration,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            connect_timeout: Duration::from_millis(500),
            io_timeout: Duration::from_secs(10),
            reprobe_after: Duration::from_secs(1),
        }
    }
}

/// One worker connection: lazily dialed, re-dialed once per call on a
/// stale socket, dropped on transport failure.
struct Link {
    addr: String,
    conn: Mutex<Option<TcpStream>>,
    /// Advisory health bit (last call's outcome) — the replica scheduler
    /// prefers live links but still probes down ones, so a restarted
    /// worker rejoins without operator action.
    healthy: AtomicBool,
    /// When the link last failed — a down link becomes eligible again
    /// once `reprobe_after` has elapsed, so rejoin does not depend on
    /// every live replica failing in the same call.
    last_failure: Mutex<Option<std::time::Instant>>,
}

impl Link {
    fn mark_down(&self) {
        self.healthy.store(false, Ordering::Relaxed);
        *crate::util::lock_recover(&self.last_failure) = Some(std::time::Instant::now());
        if crate::obs::enabled() {
            crate::obs::recorder::record(
                crate::obs::recorder::EventKind::WorkerDown,
                format!("addr={}", self.addr),
            );
        }
    }

    /// Live, or down long enough that it is worth a probe.
    fn eligible(&self, reprobe_after: Duration) -> bool {
        if self.healthy.load(Ordering::Relaxed) {
            return true;
        }
        crate::util::lock_recover(&self.last_failure)
            .map(|t| t.elapsed() >= reprobe_after)
            .unwrap_or(true)
    }
}

/// Routing front end over one placement plan.
pub struct Router {
    plan: PlacementPlan,
    links: Vec<Link>,
    config: RouterConfig,
    rr: AtomicUsize,
}

impl Router {
    /// Build a router over `plan`. No I/O happens here — connections are
    /// dialed (and handshaken) on first use, so a router can outlive
    /// workers that come and go.
    pub fn new(plan: PlacementPlan, config: RouterConfig) -> Router {
        let links = plan
            .workers
            .iter()
            .map(|w| Link {
                addr: w.addr.clone(),
                conn: Mutex::new(None),
                healthy: AtomicBool::new(true),
                last_failure: Mutex::new(None),
            })
            .collect();
        Router { plan, links, config, rr: AtomicUsize::new(0) }
    }

    pub fn plan(&self) -> &PlacementPlan {
        &self.plan
    }

    /// Does this router's plan cover the checkpoint at `path`? Paths are
    /// compared as given — the plan must name the checkpoint the way
    /// clients submit it.
    pub fn covers(&self, path: &Path) -> bool {
        Path::new(&self.plan.checkpoint) == path
    }

    /// Workers whose last interaction succeeded.
    pub fn healthy_workers(&self) -> usize {
        self.links.iter().filter(|l| l.healthy.load(Ordering::Relaxed)).count()
    }

    /// Probe every worker with a `Health` frame; returns how many
    /// answered. Updates the advisory health bits as a side effect.
    pub fn health_check(&self) -> usize {
        (0..self.links.len())
            .filter(|&i| matches!(self.call_link(i, &Frame::Health), Ok(Frame::HealthOk { .. })))
            .count()
    }

    /// Fetch per-model latency statistics from worker `idx`.
    pub fn worker_stats(&self, idx: usize) -> Result<Vec<wire::ModelStats>, String> {
        match self.call_link(idx, &Frame::Stats) {
            Ok(Frame::StatsOk { models, .. }) => Ok(models),
            Ok(other) => Err(format!("unexpected {} frame", other.name())),
            Err(e) => Err(e.to_string()),
        }
    }

    /// Fetch per-tenant admission statistics from worker `idx` (empty on
    /// a worker that serves no named tenants).
    pub fn worker_tenant_stats(&self, idx: usize) -> Result<Vec<wire::TenantStats>, String> {
        match self.call_link(idx, &Frame::Stats) {
            Ok(Frame::StatsOk { tenants, .. }) => Ok(tenants),
            Ok(other) => Err(format!("unexpected {} frame", other.name())),
            Err(e) => Err(e.to_string()),
        }
    }

    /// Workers in this router's placement plan (the fleet scrape bound).
    pub fn worker_count(&self) -> usize {
        self.links.len()
    }

    /// Everything one `Stats` exchange carries from worker `idx` — the
    /// per-model and per-tenant rows plus the protocol-v3 per-layer
    /// kernel summaries and span count. One wire call, so the metrics
    /// endpoint's fleet scrape costs one RTT per worker.
    pub fn worker_snapshot(&self, idx: usize) -> Result<WorkerObs, String> {
        match self.call_link(idx, &Frame::Stats) {
            Ok(Frame::StatsOk { models, tenants, kernels, spans }) => {
                Ok(WorkerObs { models, tenants, kernels, spans })
            }
            Ok(other) => Err(format!("unexpected {} frame", other.name())),
            Err(e) => Err(e.to_string()),
        }
    }

    /// Dial and handshake one worker.
    fn connect(&self, link: &Link) -> Result<TcpStream, WireError> {
        let addr = link
            .addr
            .to_socket_addrs()
            .map_err(WireError::Io)?
            .next()
            .ok_or_else(|| WireError::Malformed(format!("unresolvable address {:?}", link.addr)))?;
        let mut stream = TcpStream::connect_timeout(&addr, self.config.connect_timeout)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(self.config.io_timeout))?;
        stream.set_write_timeout(Some(self.config.io_timeout))?;
        let hello = Frame::Hello {
            version: PROTOCOL_VERSION,
            checkpoint_hash: self.plan.checkpoint_hash,
        };
        match wire::call(&mut stream, &hello)? {
            Frame::HelloAck { version, checkpoint_hash } => {
                if version != PROTOCOL_VERSION {
                    return Err(WireError::VersionMismatch {
                        got: version,
                        want: PROTOCOL_VERSION,
                    });
                }
                if checkpoint_hash != self.plan.checkpoint_hash {
                    return Err(WireError::HashMismatch {
                        got: checkpoint_hash,
                        want: self.plan.checkpoint_hash,
                    });
                }
                Ok(stream)
            }
            Frame::Error { code, message } => Err(WireError::Remote { code, message }),
            other => Err(WireError::Unexpected(other.name())),
        }
    }

    /// One request/response against worker `idx`. A stale cached
    /// connection (worker restarted since the last call) gets exactly one
    /// reconnect-and-retry; transport failures drop the connection and
    /// mark the link down, protocol-level `Error` answers keep it up
    /// (the worker is alive — it just refused this request).
    fn call_link(&self, idx: usize, request: &Frame) -> Result<Frame, WireError> {
        let t0 = crate::obs::now_if_enabled();
        let result = self.call_link_inner(idx, request);
        if let Some(t0) = t0 {
            crate::obs::span::record(
                "wire",
                t0,
                vec![
                    ("worker", crate::obs::span::ArgVal::U64(idx as u64)),
                    ("ok", crate::obs::span::ArgVal::U64(u64::from(result.is_ok()))),
                ],
            );
        }
        result
    }

    fn call_link_inner(&self, idx: usize, request: &Frame) -> Result<Frame, WireError> {
        let link = &self.links[idx];
        let mut guard = crate::util::lock_recover(&link.conn);
        for attempt in 0..2 {
            let had_cached = guard.is_some();
            let mut stream = match guard.take() {
                Some(s) => s,
                None => match self.connect(link) {
                    Ok(s) => s,
                    Err(e) => {
                        link.mark_down();
                        return Err(e);
                    }
                },
            };
            match wire::call(&mut stream, request) {
                Ok(Frame::Error { code, message }) => {
                    *guard = Some(stream);
                    link.healthy.store(true, Ordering::Relaxed);
                    return Err(WireError::Remote { code, message });
                }
                Ok(frame) => {
                    *guard = Some(stream);
                    link.healthy.store(true, Ordering::Relaxed);
                    return Ok(frame);
                }
                Err(e) => {
                    // Dead socket: retry once on a fresh dial if this one
                    // came from the cache, otherwise give up.
                    drop(stream);
                    if attempt == 0 && had_cached {
                        continue;
                    }
                    link.mark_down();
                    return Err(e);
                }
            }
        }
        unreachable!("loop always returns within two attempts")
    }

    /// Route one batch through the fleet. Replica: round-robin with
    /// failover across every worker. Partition: stage-to-stage through
    /// all of them. Any unrecoverable failure returns `Err` — the caller
    /// (normally [`RoutedExecutor`]) decides whether to fall back local.
    pub fn forward(&self, batch: &Mat<f32>) -> Result<Mat<f32>, String> {
        let model = self.plan.checkpoint.clone();
        match self.plan.mode {
            PlacementMode::Replica => {
                let n = self.links.len();
                let start = self.rr.fetch_add(1, Ordering::Relaxed);
                // One frame for every attempt: the request is identical
                // across replicas, and the batch clone is the expensive
                // part of a retry.
                let req = Frame::Forward { model, batch: batch.clone() };
                // Snapshot eligibility once, then try each eligible link
                // at most once: live links, plus down links whose
                // `reprobe_after` has elapsed — so a restarted replica
                // rejoins within the interval even while others keep
                // answering. Links inside their throttle window are
                // never dialed (a dead fleet costs the caller an
                // immediate local failover, not a connect timeout per
                // link per batch), and a link that fails *during* this
                // sweep is not retried — the snapshot was taken before.
                let eligible: Vec<usize> = (0..n)
                    .map(|off| (start + off) % n)
                    .filter(|&idx| self.links[idx].eligible(self.config.reprobe_after))
                    .collect();
                let mut last_err =
                    String::from("no eligible workers (all replicas recently failed)");
                for idx in eligible {
                    match self.call_link(idx, &req) {
                        Ok(Frame::ForwardOk { outputs }) => return Ok(outputs),
                        Ok(other) => {
                            last_err = format!(
                                "worker {}: unexpected {} frame",
                                self.links[idx].addr,
                                other.name()
                            );
                        }
                        Err(e) => {
                            last_err = format!("worker {}: {e}", self.links[idx].addr);
                        }
                    }
                }
                Err(last_err)
            }
            PlacementMode::Partition => {
                let mut h = batch.clone();
                for idx in 0..self.links.len() {
                    let req = Frame::Forward { model: model.clone(), batch: h };
                    match self.call_link(idx, &req) {
                        Ok(Frame::ForwardOk { outputs }) => h = outputs,
                        Ok(other) => {
                            return Err(format!(
                                "stage {idx} ({}): unexpected {} frame",
                                self.links[idx].addr,
                                other.name()
                            ))
                        }
                        Err(e) => {
                            return Err(format!(
                                "stage {idx} ({}): {e}",
                                self.links[idx].addr
                            ))
                        }
                    }
                }
                Ok(h)
            }
        }
    }
}

/// One worker's full observability snapshot from a single `Stats`
/// exchange ([`Router::worker_snapshot`]).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerObs {
    pub models: Vec<wire::ModelStats>,
    pub tenants: Vec<wire::TenantStats>,
    pub kernels: Vec<wire::KernelStats>,
    /// Spans the worker has recorded (its own obs store).
    pub spans: u64,
}

/// [`BatchExecutor`] over a [`Router`], with local failover: batches go
/// to the fleet; if the fleet cannot answer, the batch runs on the local
/// kernels (already resident via the model cache) and the failover is
/// counted in [`ServeMetrics`]. Clients never see fleet failures.
pub struct RoutedExecutor {
    router: Arc<Router>,
    local: LocalExecutor,
    metrics: Arc<ServeMetrics>,
}

impl RoutedExecutor {
    pub fn new(router: Arc<Router>, local: LocalExecutor, metrics: Arc<ServeMetrics>) -> Self {
        RoutedExecutor { router, local, metrics }
    }
}

/// Flight-record one routed→local failover (an immediate-dump trigger
/// when a postmortem directory is configured). The enable check keeps
/// the disabled path allocation-free.
fn record_failover(model: &str, reason: &str) {
    if crate::obs::enabled() {
        crate::obs::recorder::record(
            crate::obs::recorder::EventKind::Failover,
            format!("model={model} reason={reason}"),
        );
    }
}

impl BatchExecutor for RoutedExecutor {
    fn label(&self) -> &str {
        self.local.label()
    }

    fn input_dim(&self) -> usize {
        self.local.input_dim()
    }

    fn execute(&self, inputs: Mat<f32>) -> Result<Vec<Vec<f32>>, String> {
        match self.router.forward(&inputs) {
            Ok(out) if out.rows() == inputs.rows() => {
                self.metrics.routed_batches.fetch_add(1, Ordering::Relaxed);
                Ok((0..out.rows()).map(|r| out.row(r).to_vec()).collect())
            }
            Ok(out) => {
                log::warn!(
                    "routed batch answered {} rows for {} inputs — failing over to local",
                    out.rows(),
                    inputs.rows()
                );
                self.metrics.failovers.fetch_add(1, Ordering::Relaxed);
                record_failover(self.local.label(), "row-count mismatch");
                self.local.execute(inputs)
            }
            Err(e) => {
                log::warn!("routed batch failed ({e}) — failing over to local");
                self.metrics.failovers.fetch_add(1, Ordering::Relaxed);
                record_failover(self.local.label(), &e);
                self.local.execute(inputs)
            }
        }
    }
}
