//! Shard-placement planning: which worker serves which layers.
//!
//! The planner reads a checkpoint's per-layer metadata (one header pass —
//! no payload I/O) and partitions the layer chain across N workers by a
//! cost model with two axes:
//!
//! * **stored bytes** — what a worker must hold resident (and read from
//!   its shards): `4·(C·D + bias)` dense, `4·(k(C+D) + bias)` factored;
//! * **MACs per sample** — what a worker must compute per request:
//!   `C·D` dense vs `k(C+D)` factored (§3's two-small-GEMMs rewrite),
//!   plus the bias add.
//!
//! The same layer-wise accounting that gives SVD-NAS its per-layer
//! budgets tells the planner which layers are cheap (factored) vs
//! expensive (dense passthrough), so placement balances *compute*, not
//! just bytes: each layer's load is its normalized share of both axes,
//! and the partitioner minimizes the maximum per-worker load over all
//! contiguous splits (layers must stay contiguous — a stage hands its
//! activations to the next stage over the wire).
//!
//! Two modes:
//!
//! * [`PlacementMode::Replica`] — every worker serves the whole model;
//!   the router spreads whole batches across replicas.
//! * [`PlacementMode::Partition`] — the chain is split into contiguous
//!   stages; the router pipes each batch stage-to-stage.
//!
//! The plan is a TOML document (same `config::toml` subset as experiment
//! configs and shard manifests) shared by `rsic plan`, `rsic worker` and
//! `rsic serve --plan`, and it embeds a checkpoint identity hash
//! ([`checkpoint_identity_hash`]) that the wire handshake cross-checks so
//! a router never routes at a worker serving different bytes.

use crate::config::toml::{toml_quote, TomlDoc};
use crate::io::checkpoint::{bias_key, layer_infos_from, CheckpointSource, WeightSource};
use crate::io::tenz::Fnv1a;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Plan schema version this build reads and writes.
pub const PLAN_VERSION: i64 = 1;

/// How the model is spread across workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementMode {
    /// Whole model on every worker; batches route to one replica each.
    Replica,
    /// Contiguous layer stages; batches flow worker-to-worker.
    Partition,
}

impl PlacementMode {
    pub fn name(self) -> &'static str {
        match self {
            PlacementMode::Replica => "replica",
            PlacementMode::Partition => "partition",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "replica" => Ok(PlacementMode::Replica),
            "partition" => Ok(PlacementMode::Partition),
            other => bail!("unknown placement mode {other:?} (replica|partition)"),
        }
    }
}

/// One layer's placement cost (both axes of the cost model).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerCost {
    pub layer: String,
    /// Stored bytes: 4 bytes per parameter (weights + bias) as served.
    pub bytes: u64,
    /// Fused multiply-adds per served sample (dense `C·D`, factored
    /// `k(C+D)`, plus the bias add).
    pub macs: u64,
}

/// Per-layer costs from one header-only metadata pass, in forward order.
pub fn layer_costs(src: &dyn WeightSource) -> Vec<LayerCost> {
    layer_infos_from(src)
        .into_iter()
        .map(|info| {
            let bias = src
                .dims_of(&bias_key(&info.layer))
                .map(|d| d.iter().product::<usize>())
                .unwrap_or(0);
            let params = info.stored_params as u64 + bias as u64;
            LayerCost { layer: info.layer, bytes: params * 4, macs: params }
        })
        .collect()
}

/// One worker's slice of the plan.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkerAssignment {
    /// Where the router reaches this worker (`host:port`). May be empty
    /// while a plan is under construction (tests bind ephemeral ports and
    /// fill addresses in after spawn).
    pub addr: String,
    /// Layers this worker serves, in forward order. Empty means the
    /// whole model (replica mode).
    pub layers: Vec<String>,
    /// Stored bytes across the assignment (cost-model bookkeeping).
    pub bytes: u64,
    /// MACs per sample across the assignment.
    pub macs: u64,
}

/// A complete placement: checkpoint identity + per-worker assignments.
#[derive(Debug, Clone, PartialEq)]
pub struct PlacementPlan {
    /// Checkpoint path as the cluster's nodes resolve it.
    pub checkpoint: String,
    /// Identity hash of the checkpoint bytes (see
    /// [`checkpoint_identity_hash`]); carried by the wire handshake.
    pub checkpoint_hash: u64,
    pub mode: PlacementMode,
    pub workers: Vec<WorkerAssignment>,
}

impl PlacementPlan {
    /// Plan `checkpoint` across `addrs.len()` workers. Partition mode
    /// splits the layer chain by the cost model; replica mode assigns the
    /// whole model everywhere. Metadata comes from one header pass over
    /// `src` — no payload I/O.
    pub fn build(
        src: &dyn WeightSource,
        checkpoint: &str,
        checkpoint_hash: u64,
        mode: PlacementMode,
        addrs: &[String],
    ) -> Result<PlacementPlan> {
        anyhow::ensure!(!addrs.is_empty(), "a placement plan needs at least one worker");
        let costs = layer_costs(src);
        anyhow::ensure!(
            !costs.is_empty(),
            "checkpoint {checkpoint} has no 2-D linear layers to place"
        );
        let total_bytes: u64 = costs.iter().map(|c| c.bytes).sum();
        let total_macs: u64 = costs.iter().map(|c| c.macs).sum();
        let workers = match mode {
            PlacementMode::Replica => addrs
                .iter()
                .map(|addr| WorkerAssignment {
                    addr: addr.clone(),
                    layers: Vec::new(),
                    bytes: total_bytes,
                    macs: total_macs,
                })
                .collect(),
            PlacementMode::Partition => {
                anyhow::ensure!(
                    addrs.len() <= costs.len(),
                    "cannot partition {} layers across {} workers",
                    costs.len(),
                    addrs.len()
                );
                let loads: Vec<f64> = costs
                    .iter()
                    .map(|c| {
                        c.bytes as f64 / total_bytes.max(1) as f64
                            + c.macs as f64 / total_macs.max(1) as f64
                    })
                    .collect();
                let bounds = partition_contiguous(&loads, addrs.len());
                let mut out = Vec::with_capacity(addrs.len());
                let mut start = 0usize;
                for (addr, end) in addrs.iter().zip(bounds) {
                    let slice = &costs[start..end];
                    out.push(WorkerAssignment {
                        addr: addr.clone(),
                        layers: slice.iter().map(|c| c.layer.clone()).collect(),
                        bytes: slice.iter().map(|c| c.bytes).sum(),
                        macs: slice.iter().map(|c| c.macs).sum(),
                    });
                    start = end;
                }
                out
            }
        };
        Ok(PlacementPlan {
            checkpoint: checkpoint.to_string(),
            checkpoint_hash,
            mode,
            workers,
        })
    }

    /// Partition plans must tile the checkpoint's layer chain exactly —
    /// every layer once, in forward order, no skips. A plan that doesn't
    /// (hand-edited, or stale after a recompression changed the layer
    /// set) could serve silently wrong outputs whenever stage widths
    /// happen to line up, so workers refuse it at model load rather than
    /// trust it. Replica plans always pass (empty assignment = whole
    /// model, resolved at load).
    pub fn validate_layers(&self, src: &dyn WeightSource) -> Result<()> {
        if self.mode != PlacementMode::Partition {
            return Ok(());
        }
        let expected: Vec<String> =
            layer_infos_from(src).into_iter().map(|i| i.layer).collect();
        let got: Vec<&String> = self.workers.iter().flat_map(|w| w.layers.iter()).collect();
        let tiles =
            got.len() == expected.len() && got.iter().zip(&expected).all(|(a, b)| **a == *b);
        anyhow::ensure!(
            tiles,
            "partition plan does not tile the checkpoint's layer chain: plan stages hold \
             [{}], checkpoint has [{}]",
            got.iter().map(|s| s.as_str()).collect::<Vec<_>>().join(", "),
            expected.join(", ")
        );
        Ok(())
    }

    /// Combined normalized load of one assignment: its share of total
    /// stored bytes plus its share of total MACs (so a perfectly balanced
    /// partition across W workers gives every worker 2/W).
    pub fn load_of(&self, w: &WorkerAssignment) -> f64 {
        let total_bytes: u64 = self.workers.iter().map(|a| a.bytes).sum();
        let total_macs: u64 = self.workers.iter().map(|a| a.macs).sum();
        w.bytes as f64 / total_bytes.max(1) as f64 + w.macs as f64 / total_macs.max(1) as f64
    }

    /// Balance metric the acceptance gate checks: the heaviest worker's
    /// load over the mean load (1.0 = perfectly balanced).
    pub fn max_over_mean_load(&self) -> f64 {
        if self.workers.is_empty() {
            return 1.0;
        }
        let loads: Vec<f64> = self.workers.iter().map(|w| self.load_of(w)).collect();
        let mean = loads.iter().sum::<f64>() / loads.len() as f64;
        if mean <= 0.0 {
            return 1.0;
        }
        loads.into_iter().fold(0.0f64, f64::max) / mean
    }

    /// Render as TOML (the exact text [`write`](Self::write) emits).
    pub fn to_toml_string(&self) -> String {
        let mut out = String::new();
        out.push_str("# rsic cluster placement plan (DESIGN.md §Cluster)\n");
        out.push_str(&format!("version = {PLAN_VERSION}\n"));
        out.push_str(&format!("checkpoint = {}\n", toml_quote(&self.checkpoint)));
        out.push_str(&format!("checkpoint_hash = \"{:016x}\"\n", self.checkpoint_hash));
        out.push_str(&format!("mode = \"{}\"\n", self.mode.name()));
        out.push_str(&format!("workers = {}\n", self.workers.len()));
        for (i, w) in self.workers.iter().enumerate() {
            out.push_str(&format!("\n[worker.{i}]\n"));
            out.push_str(&format!("addr = {}\n", toml_quote(&w.addr)));
            let layers: Vec<String> = w.layers.iter().map(|l| toml_quote(l)).collect();
            out.push_str(&format!("layers = [{}]\n", layers.join(", ")));
            out.push_str(&format!("bytes = {}\n", w.bytes));
            out.push_str(&format!("macs = {}\n", w.macs));
        }
        out
    }

    /// Parse plan text. Structural problems surface as errors, never
    /// panics — same contract as the shard-manifest parser.
    pub fn parse(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text).context("placement plan is not valid TOML")?;
        let version = doc.int("version").context("placement plan: version")?;
        anyhow::ensure!(
            version == PLAN_VERSION,
            "unsupported plan version {version} (this build reads {PLAN_VERSION})"
        );
        let checkpoint = doc.str("checkpoint").context("placement plan: checkpoint")?.to_string();
        let hash_hex = doc.str("checkpoint_hash").context("placement plan: checkpoint_hash")?;
        let checkpoint_hash = u64::from_str_radix(hash_hex, 16)
            .with_context(|| format!("placement plan: bad checkpoint_hash {hash_hex:?}"))?;
        let mode = PlacementMode::parse(doc.str("mode").context("placement plan: mode")?)?;
        let count = doc.int("workers").context("placement plan: workers")?;
        let count = usize::try_from(count)
            .map_err(|_| anyhow::anyhow!("placement plan: negative worker count {count}"))?;
        let mut workers = Vec::with_capacity(count.min(4096));
        for i in 0..count {
            let addr = doc
                .str(&format!("worker.{i}.addr"))
                .with_context(|| format!("placement plan: worker {i} addr"))?
                .to_string();
            let layers_val = doc
                .get(&format!("worker.{i}.layers"))
                .with_context(|| format!("placement plan: worker {i} layers"))?;
            let arr = layers_val
                .as_array()
                .with_context(|| format!("placement plan: worker {i} layers is not an array"))?;
            let layers = arr
                .iter()
                .map(|v| {
                    v.as_str().map(str::to_string).with_context(|| {
                        format!("placement plan: worker {i} has a non-string layer name")
                    })
                })
                .collect::<Result<Vec<String>>>()?;
            let bytes = doc.int(&format!("worker.{i}.bytes")).unwrap_or(0).max(0) as u64;
            let macs = doc.int(&format!("worker.{i}.macs")).unwrap_or(0).max(0) as u64;
            workers.push(WorkerAssignment { addr, layers, bytes, macs });
        }
        anyhow::ensure!(!workers.is_empty(), "placement plan has no workers");
        if mode == PlacementMode::Partition {
            anyhow::ensure!(
                workers.iter().all(|w| !w.layers.is_empty()),
                "partition plan has a worker with no layers"
            );
        }
        Ok(PlacementPlan { checkpoint, checkpoint_hash, mode, workers })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading placement plan {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing placement plan {}", path.display()))
    }

    /// Write atomically via a temp sibling, like every manifest write.
    pub fn write(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let tmp = crate::io::tenz::tmp_sibling(path);
        std::fs::write(&tmp, self.to_toml_string())
            .and_then(|()| std::fs::rename(&tmp, path))
            .map_err(|e| {
                let _ = std::fs::remove_file(&tmp);
                anyhow::anyhow!("writing placement plan {}: {e}", path.display())
            })
    }
}

/// Split `loads` into `groups` non-empty contiguous runs minimizing the
/// maximum per-group sum (the classic linear-partition DP — O(n²·g),
/// which is nothing at checkpoint scale). Returns the exclusive end
/// index of each group.
fn partition_contiguous(loads: &[f64], groups: usize) -> Vec<usize> {
    let n = loads.len();
    debug_assert!(groups >= 1 && groups <= n);
    let mut prefix = vec![0.0f64; n + 1];
    for (i, l) in loads.iter().enumerate() {
        prefix[i + 1] = prefix[i] + l;
    }
    let sum = |a: usize, b: usize| prefix[b] - prefix[a]; // [a, b)
    // dp[g][i]: minimal max-group-sum splitting the first i items into g
    // groups; cut[g][i]: where the last group starts in that optimum.
    let mut dp = vec![vec![f64::INFINITY; n + 1]; groups + 1];
    let mut cut = vec![vec![0usize; n + 1]; groups + 1];
    dp[0][0] = 0.0;
    for g in 1..=groups {
        for i in g..=n {
            for j in (g - 1)..i {
                let candidate = dp[g - 1][j].max(sum(j, i));
                if candidate < dp[g][i] {
                    dp[g][i] = candidate;
                    cut[g][i] = j;
                }
            }
        }
    }
    let mut bounds = vec![0usize; groups];
    let mut i = n;
    for g in (1..=groups).rev() {
        bounds[g - 1] = i;
        i = cut[g][i];
    }
    bounds
}

/// Cheap identity hash of an **already-open** checkpoint — the value
/// the wire handshake compares so router and workers agree on *which
/// bytes* they serve. Sharded checkpoints hash the manifest's per-shard
/// content records
/// ([`identity_hash`](crate::io::shard::ShardManifest::identity_hash) —
/// O(manifest), and the shard hashes already cover the payload). Single `.tenz`
/// containers hash the indexed header (names, dtypes, dims, offsets,
/// sizes) — no further I/O; content-level rot there is `rsic verify`'s
/// job, not the handshake's. Taking the open source (rather than a
/// path) means the hash describes the same bytes the caller's cost
/// model and layer list were computed from — no second open, no
/// replaced-between-opens window.
pub fn checkpoint_identity_hash_of(src: &CheckpointSource) -> u64 {
    match src {
        CheckpointSource::Sharded(s) => s.manifest().identity_hash(),
        CheckpointSource::Single(r) => {
            let mut h = Fnv1a::new();
            for meta in r.tenz().metas() {
                h.update(meta.name.as_bytes());
                h.update(&[0, meta.dtype.size() as u8]);
                h.update(&(meta.dims.len() as u64).to_le_bytes());
                for d in &meta.dims {
                    h.update(&(*d as u64).to_le_bytes());
                }
                h.update(&meta.offset.to_le_bytes());
                h.update(&meta.nbytes.to_le_bytes());
            }
            h.finish()
        }
    }
}

/// Path convenience over [`checkpoint_identity_hash_of`] for callers
/// that hold no open source (the worker-side tests, say). Callers that
/// already opened the checkpoint should hash that source instead.
pub fn checkpoint_identity_hash(path: impl AsRef<Path>) -> Result<u64> {
    let path = path.as_ref();
    let src = CheckpointSource::open(path)
        .with_context(|| format!("opening checkpoint {}", path.display()))?;
    Ok(checkpoint_identity_hash_of(&src))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::checkpoint::{store_weight, StoredWeight};
    use crate::io::tenz::{TensorEntry, TensorFile};
    use crate::tensor::Mat;

    /// A chain checkpoint with per-layer output widths `dims[i+1]` and a
    /// factored layer wherever `ranks[i]` is Some.
    fn chain(dims: &[usize], ranks: &[Option<usize>]) -> TensorFile {
        let mut tf = TensorFile::new();
        for i in 0..dims.len() - 1 {
            let (d, c) = (dims[i], dims[i + 1]);
            let w = match ranks[i] {
                None => StoredWeight::Dense(Mat::zeros(c, d)),
                Some(k) => {
                    StoredWeight::Factored { a: Mat::zeros(c, k), b: Mat::zeros(k, d) }
                }
            };
            store_weight(&mut tf, &format!("layers.{i}"), &w);
            tf.insert(format!("layers.{i}.bias"), TensorEntry::from_f32(vec![c], &vec![0.0; c]));
        }
        tf
    }

    #[test]
    fn layer_costs_cover_both_representations() {
        let tf = chain(&[10, 20, 6], &[None, Some(2)]);
        let costs = layer_costs(&tf);
        assert_eq!(costs.len(), 2);
        // Dense 20×10 + bias 20 → 220 params; factored 2·(6+20) + bias 6 → 58.
        assert_eq!(costs[0].macs, 220);
        assert_eq!(costs[0].bytes, 220 * 4);
        assert_eq!(costs[1].macs, 58);
        assert_eq!(costs[1].layer, "layers.1");
    }

    #[test]
    fn partition_dp_is_balanced_and_contiguous() {
        let loads = [5.0, 1.0, 1.0, 1.0, 1.0, 5.0];
        let bounds = partition_contiguous(&loads, 3);
        assert_eq!(bounds.len(), 3);
        assert_eq!(*bounds.last().unwrap(), loads.len());
        // Optimal split is [5], [1,1,1,1], [5] — max group sum 5.
        assert_eq!(bounds, vec![1, 5, 6]);
    }

    #[test]
    fn plan_roundtrips_through_toml() {
        let tf = chain(&[8, 16, 12, 4], &[None, Some(3), None]);
        let addrs = vec!["127.0.0.1:7101".to_string(), "127.0.0.1:7102".to_string()];
        let plan = PlacementPlan::build(&tf, "m.toml", 0xabc, PlacementMode::Partition, &addrs)
            .unwrap();
        assert_eq!(plan.workers.len(), 2);
        let all: Vec<String> =
            plan.workers.iter().flat_map(|w| w.layers.iter().cloned()).collect();
        assert_eq!(all, vec!["layers.0", "layers.1", "layers.2"], "stages stay contiguous");
        let back = PlacementPlan::parse(&plan.to_toml_string()).unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn replica_plan_assigns_whole_model() {
        let tf = chain(&[8, 4], &[None]);
        let addrs = vec!["a:1".to_string(), "b:2".to_string(), "c:3".to_string()];
        let plan =
            PlacementPlan::build(&tf, "m.tenz", 7, PlacementMode::Replica, &addrs).unwrap();
        assert_eq!(plan.workers.len(), 3);
        assert!(plan.workers.iter().all(|w| w.layers.is_empty()));
        assert!((plan.max_over_mean_load() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn partition_plan_must_tile_the_layer_chain() {
        // Equal widths everywhere: the dangerous case, where a skipped
        // layer still chains and would serve silently wrong outputs.
        let tf = chain(&[8, 8, 8, 8], &[None, None, None]);
        let addrs = vec!["a:1".to_string(), "b:2".to_string()];
        let plan =
            PlacementPlan::build(&tf, "m", 0, PlacementMode::Partition, &addrs).unwrap();
        plan.validate_layers(&tf).unwrap();
        // Drop a mid-chain layer from its stage: refused.
        let mut skipped = plan.clone();
        for w in skipped.workers.iter_mut() {
            w.layers.retain(|l| l != "layers.1");
        }
        assert!(skipped.validate_layers(&tf).is_err());
        // Reorder two layers: refused.
        let mut swapped = plan.clone();
        let flat: Vec<String> =
            swapped.workers.iter().flat_map(|w| w.layers.iter().cloned()).collect();
        assert_eq!(flat.len(), 3);
        swapped.workers[0].layers = vec![flat[1].clone(), flat[0].clone()];
        swapped.workers[1].layers = flat[2..].to_vec();
        assert!(swapped.validate_layers(&tf).is_err());
        // Replica plans (empty assignments) always pass.
        let replica =
            PlacementPlan::build(&tf, "m", 0, PlacementMode::Replica, &addrs).unwrap();
        replica.validate_layers(&tf).unwrap();
    }

    #[test]
    fn bad_plans_are_rejected() {
        assert!(PlacementPlan::parse("not toml [").is_err());
        assert!(PlacementPlan::parse("version = 99\n").is_err());
        let missing_workers =
            "version = 1\ncheckpoint = \"m\"\ncheckpoint_hash = \"0\"\nmode = \"replica\"\nworkers = 0\n";
        assert!(PlacementPlan::parse(missing_workers).is_err());
        let empty_stage = "version = 1\ncheckpoint = \"m\"\ncheckpoint_hash = \"0\"\n\
                           mode = \"partition\"\nworkers = 1\n[worker.0]\naddr = \"a\"\nlayers = []\n";
        assert!(PlacementPlan::parse(empty_stage).is_err());
        let tf = chain(&[4, 4], &[None]);
        let too_many: Vec<String> = (0..3).map(|i| format!("w{i}")).collect();
        assert!(
            PlacementPlan::build(&tf, "m", 0, PlacementMode::Partition, &too_many).is_err(),
            "1 layer cannot partition across 3 workers"
        );
    }
}
