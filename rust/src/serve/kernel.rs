//! Per-layer execution kernels: the inference-side payoff of compression.
//!
//! A dense layer computes `y = Wx` in one C×D GEMM; a factored layer
//! computes `y = U(Vᵀx)` as two skinny GEMMs costing k(C+D) — the paper's
//! two-small-linear-layers rewrite (§3), which is why a compressed
//! checkpoint serves cheaper than the dense one at α below the k(C+D) <
//! C·D break-even. Both kernels run whole micro-batches through
//! [`gemm::matvec_batch`], so a coalesced batch of N requests is one (or
//! two) threaded GEMMs, never N matvecs.
//!
//! Bias and ReLU are not a separate pass: every kernel takes a
//! [`gemm::Epilogue`] that the GEMM applies during write-back, and the
//! layer chain in [`ModelKernels::forward`] ping-pongs two scratch
//! buffers (plus one shared mid-GEMM buffer) so a forward pass allocates
//! nothing per layer after the first batch shape is seen.

use crate::io::checkpoint::{
    bias_key, layer_infos_from, load_weight_from, StoredWeight, WeightSource,
};
use crate::linalg::gemm;
use crate::tensor::{Mat, QuantMat};
use anyhow::{Context, Result};

/// Dense kernel: `y = Wx` over the stored C×D weight.
#[derive(Debug, Clone)]
pub struct DenseLinear {
    /// C×D weight.
    pub w: Mat<f32>,
}

/// Factored kernel: `y = U(Vᵀx)` over the stored factors, never
/// reconstructing U·Vᵀ. (`U` is the checkpoint's `weight.A`, `V`ᵀ its
/// `weight.B`.)
#[derive(Debug, Clone)]
pub struct FactoredLinear {
    /// C×k left factor.
    pub u: Mat<f32>,
    /// k×D right factor (already transposed: rows are the k basis vectors).
    pub vt: Mat<f32>,
}

/// Quantized factored kernel: the same `y = U(Vᵀx)` rewrite over per-row
/// i8 factors (`--store-dtype i8`). Accumulation is f32 against the raw
/// codes; the row scale is applied once per output — the factors are
/// never dequantized into a float matrix.
#[derive(Debug, Clone)]
pub struct QuantFactoredLinear {
    /// C×k left factor (per-output-row scales).
    pub u: QuantMat,
    /// k×D right factor (per-rank-row scales).
    pub vt: QuantMat,
}

/// One layer's execution kernel, chosen by how the checkpoint stores it.
#[derive(Debug, Clone)]
pub enum LinearKernel {
    Dense(DenseLinear),
    Factored(FactoredLinear),
    QuantizedFactored(QuantFactoredLinear),
}

/// Reshape a recycled scratch vector into an all-zero rows×cols matrix.
fn recycle(mut buf: Vec<f32>, rows: usize, cols: usize) -> Mat<f32> {
    buf.clear();
    buf.resize(rows * cols, 0.0);
    Mat::from_vec(rows, cols, buf)
}

impl LinearKernel {
    pub fn from_stored(w: StoredWeight) -> LinearKernel {
        match w {
            StoredWeight::Dense(w) => LinearKernel::Dense(DenseLinear { w }),
            StoredWeight::Factored { a, b } => {
                LinearKernel::Factored(FactoredLinear { u: a, vt: b })
            }
            StoredWeight::QuantizedFactored { a, b } => {
                LinearKernel::QuantizedFactored(QuantFactoredLinear { u: a, vt: b })
            }
        }
    }

    /// Logical (C, D) shape: inputs are D-vectors, outputs C-vectors.
    pub fn shape(&self) -> (usize, usize) {
        match self {
            LinearKernel::Dense(d) => d.w.shape(),
            LinearKernel::Factored(f) => (f.u.rows(), f.vt.cols()),
            LinearKernel::QuantizedFactored(f) => (f.u.rows(), f.vt.cols()),
        }
    }

    /// Factorization rank (`None` for dense).
    pub fn rank(&self) -> Option<usize> {
        match self {
            LinearKernel::Dense(_) => None,
            LinearKernel::Factored(f) => Some(f.u.cols()),
            LinearKernel::QuantizedFactored(f) => Some(f.u.cols()),
        }
    }

    /// Push a batch of row vectors (N×D) through the layer → N×C, applying
    /// `epi` (bias/ReLU) inside the final GEMM's write-back. `y` must
    /// already be N×C (its contents are overwritten); `mid` is recycled
    /// scratch for the factored forms' N×k intermediate, grown on demand
    /// and handed back so the caller can reuse it across layers.
    pub fn forward_fused(
        &self,
        x: &Mat<f32>,
        epi: gemm::Epilogue<'_, f32>,
        y: &mut Mat<f32>,
        mid: &mut Vec<f32>,
    ) {
        match self {
            LinearKernel::Dense(d) => gemm::matvec_batch_fused(x, &d.w, epi, y),
            LinearKernel::Factored(f) => {
                // (N×D)·Vᵀ → N×k, then ·U → N×C: k(C+D) MACs per sample.
                let mut h = recycle(std::mem::take(mid), x.rows(), f.vt.rows());
                gemm::matvec_batch_fused(x, &f.vt, gemm::Epilogue::none(), &mut h);
                gemm::matvec_batch_fused(&h, &f.u, epi, y);
                *mid = h.into_vec();
            }
            LinearKernel::QuantizedFactored(f) => {
                let mut h = recycle(std::mem::take(mid), x.rows(), f.vt.rows());
                gemm::matvec_batch_quant(x, &f.vt, gemm::Epilogue::none(), &mut h);
                gemm::matvec_batch_quant(&h, &f.u, epi, y);
                *mid = h.into_vec();
            }
        }
    }

    /// Push a batch of row vectors (N×D) through the layer → N×C.
    pub fn forward(&self, x: &Mat<f32>) -> Mat<f32> {
        let mut y = Mat::zeros(x.rows(), self.shape().0);
        self.forward_fused(x, gemm::Epilogue::none(), &mut y, &mut Vec::new());
        y
    }

    /// Fused multiply-adds per served sample: C·D dense, k(C+D) factored —
    /// the quantity the throughput bench compares.
    pub fn flops_per_sample(&self) -> usize {
        let (c, d) = self.shape();
        match self.rank() {
            None => c * d,
            Some(k) => k * (c + d),
        }
    }

    /// Stored parameter count (dense C·D, factored (C+D)·k).
    pub fn param_count(&self) -> usize {
        match self {
            LinearKernel::Dense(d) => d.w.len(),
            LinearKernel::Factored(f) => f.u.len() + f.vt.len(),
            LinearKernel::QuantizedFactored(f) => f.u.len() + f.vt.len(),
        }
    }
}

/// Fold one timed layer forward into the obs registries: the per-layer
/// GEMM histogram plus a `"gemm"` span. Only reached when obs was
/// enabled at the time the timer was taken, and strictly *after* the
/// numeric work — the instrumentation reads the clock, never the data.
fn record_layer_obs(layer: &ServeLayer, rows: usize, t0: std::time::Instant) {
    use crate::obs::span::ArgVal;
    // FLOPs = 2 × MACs × rows, the throughput bench's accounting.
    let flops = 2 * layer.kernel.flops_per_sample() as u64 * rows as u64;
    crate::obs::layers::record(&layer.name, rows as u64, flops, t0.elapsed());
    crate::obs::span::record(
        "gemm",
        t0,
        vec![
            ("layer", ArgVal::Str(layer.name.clone())),
            ("rows", ArgVal::U64(rows as u64)),
            ("flops", ArgVal::U64(flops)),
        ],
    );
}

/// One servable layer: kernel + optional bias + activation.
#[derive(Debug, Clone)]
pub struct ServeLayer {
    pub name: String,
    pub kernel: LinearKernel,
    /// Added per output feature when present (length C).
    pub bias: Option<Vec<f32>>,
    /// ReLU after the affine map (every layer except the head).
    pub relu: bool,
}

impl ServeLayer {
    /// Forward one batch (N×D → N×C) into a caller-provided output matrix,
    /// applying bias and ReLU inside the GEMM epilogue — no second pass
    /// over `y`. `y` must be N×C; `mid` is shared factored-form scratch.
    pub fn forward_into(&self, x: &Mat<f32>, y: &mut Mat<f32>, mid: &mut Vec<f32>) {
        let epi = gemm::Epilogue { bias: self.bias.as_deref(), relu: self.relu };
        self.kernel.forward_fused(x, epi, y, mid);
    }

    /// Forward one batch (N×D → N×C) through kernel, bias, activation.
    pub fn forward(&self, x: &Mat<f32>) -> Mat<f32> {
        let mut y = Mat::zeros(x.rows(), self.kernel.shape().0);
        self.forward_into(x, &mut y, &mut Vec::new());
        y
    }
}

/// The executable form of a checkpoint: one kernel per linear layer, in
/// forward order, with ReLU between hidden layers and a bare affine head —
/// the same MLP-chain semantics the evaluator's forward artifact encodes
/// for the synth models. Built once per checkpoint and shared (behind an
/// `Arc`) by every batch the server runs against it.
#[derive(Debug, Clone)]
pub struct ModelKernels {
    pub layers: Vec<ServeLayer>,
}

impl ModelKernels {
    /// Assemble kernels from any checkpoint source (eager or lazy): layer
    /// metadata comes from one header pass, then each layer's stored
    /// representation is materialized exactly once — factored layers stay
    /// factored (U·Vᵀ is never formed). Fails on checkpoints whose layers
    /// don't chain (D of layer i+1 must equal C of layer i): serving
    /// supports MLP-chain checkpoints, which is what the pipeline emits.
    pub fn load(src: &dyn WeightSource) -> Result<ModelKernels> {
        let infos = layer_infos_from(src);
        anyhow::ensure!(!infos.is_empty(), "checkpoint has no 2-D linear layers to serve");
        let names: Vec<String> = infos.into_iter().map(|i| i.layer).collect();
        Self::load_subset(src, &names, true)
    }

    /// Assemble kernels for a contiguous slice of a checkpoint's layer
    /// chain — the partitioned-serving loader: a cluster worker serving a
    /// middle stage loads only its assigned layers (on a sharded
    /// checkpoint, only their shards are ever opened). `final_stage`
    /// says whether this slice ends the model: the last loaded layer is a
    /// bare affine head only then — a stage boundary cut mid-chain keeps
    /// its ReLU, so stage-to-stage execution is bit-identical to the
    /// single-process pass. Layers must still chain within the slice.
    pub fn load_subset(
        src: &dyn WeightSource,
        names: &[String],
        final_stage: bool,
    ) -> Result<ModelKernels> {
        anyhow::ensure!(!names.is_empty(), "no layers to serve in this assignment");
        let n = names.len();
        let mut layers = Vec::with_capacity(n);
        for (i, name) in names.iter().enumerate() {
            let stored = load_weight_from(src, name)
                .with_context(|| format!("loading layer {name}"))?;
            let kernel = LinearKernel::from_stored(stored);
            let (c, _) = kernel.shape();
            let key = bias_key(name);
            let bias = if src.contains(&key) {
                let b = src
                    .entry(&key)
                    .and_then(|e| e.to_f32())
                    .with_context(|| format!("loading bias {key}"))?;
                anyhow::ensure!(
                    b.len() == c,
                    "{key}: {} values for a {c}-output layer",
                    b.len()
                );
                Some(b)
            } else {
                None
            };
            let relu = i + 1 < n || !final_stage;
            layers.push(ServeLayer { name: name.clone(), kernel, bias, relu });
        }
        for pair in layers.windows(2) {
            let (c_prev, _) = pair[0].kernel.shape();
            let (_, d_next) = pair[1].kernel.shape();
            anyhow::ensure!(
                c_prev == d_next,
                "layers {} → {} don't chain: {} outputs vs {} inputs",
                pair[0].name,
                pair[1].name,
                c_prev,
                d_next
            );
        }
        Ok(ModelKernels { layers })
    }

    /// Input feature width (D of the first layer).
    pub fn input_dim(&self) -> usize {
        self.layers[0].kernel.shape().1
    }

    /// Output width (C of the last layer).
    pub fn output_dim(&self) -> usize {
        self.layers.last().expect("load guarantees ≥1 layer").kernel.shape().0
    }

    /// Forward a batch of row vectors (N×input_dim → N×output_dim). Two
    /// activation buffers ping-pong down the chain (layer i's input
    /// becomes layer i+1's output scratch) and one mid-GEMM buffer is
    /// shared by every factored layer — no per-layer allocation.
    pub fn forward(&self, x: &Mat<f32>) -> Mat<f32> {
        assert_eq!(x.cols(), self.input_dim(), "batch width vs model input dim");
        let n = x.rows();
        let mut mid = Vec::new();
        let mut cur = recycle(Vec::new(), n, self.layers[0].kernel.shape().0);
        let t0 = crate::obs::now_if_enabled();
        self.layers[0].forward_into(x, &mut cur, &mut mid);
        if let Some(t0) = t0 {
            record_layer_obs(&self.layers[0], n, t0);
        }
        let mut spare = Vec::new();
        for layer in &self.layers[1..] {
            let mut y = recycle(spare, n, layer.kernel.shape().0);
            let t0 = crate::obs::now_if_enabled();
            layer.forward_into(&cur, &mut y, &mut mid);
            if let Some(t0) = t0 {
                record_layer_obs(layer, n, t0);
            }
            spare = cur.into_vec();
            cur = y;
        }
        cur
    }

    /// Total stored parameters across layers.
    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.kernel.param_count()).sum()
    }

    /// Fused multiply-adds per served sample across layers.
    pub fn flops_per_sample(&self) -> usize {
        self.layers.iter().map(|l| l.kernel.flops_per_sample()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::checkpoint::store_weight;
    use crate::io::tenz::{TensorEntry, TensorFile};
    use crate::linalg::gemm::matmul;
    use crate::rng::GaussianSource;
    use crate::tensor::init::gaussian;

    #[test]
    fn factored_forward_matches_dense_reconstruction() {
        let mut g = GaussianSource::new(1);
        let u = gaussian(7, 3, 1.0, &mut g);
        let vt = gaussian(3, 11, 1.0, &mut g);
        let w = matmul(&u, &vt);
        let x = gaussian(5, 11, 1.0, &mut g);
        let dense = LinearKernel::Dense(DenseLinear { w });
        let fact = LinearKernel::Factored(FactoredLinear { u, vt });
        let yd = dense.forward(&x);
        let yf = fact.forward(&x);
        assert_eq!(yd.shape(), (5, 7));
        assert!(yd.sub(&yf).max_abs() < 1e-4, "diff {}", yd.sub(&yf).max_abs());
        assert_eq!(dense.flops_per_sample(), 7 * 11);
        assert_eq!(fact.flops_per_sample(), 3 * (7 + 11));
        assert_eq!(fact.rank(), Some(3));
    }

    #[test]
    fn model_load_and_forward_chain() {
        let mut g = GaussianSource::new(2);
        let mut tf = TensorFile::new();
        // 6 → 4 (relu) → 3 head, with biases; layer 0 factored.
        let (a, b) = (gaussian(4, 2, 1.0, &mut g), gaussian(2, 6, 1.0, &mut g));
        store_weight(&mut tf, "layers.0", &StoredWeight::Factored { a, b });
        tf.insert("layers.0.bias", TensorEntry::from_f32(vec![4], &[0.1; 4]));
        store_weight(&mut tf, "head", &StoredWeight::Dense(gaussian(3, 4, 1.0, &mut g)));
        tf.insert("head.bias", TensorEntry::from_f32(vec![3], &[-0.2; 3]));

        let model = ModelKernels::load(&tf).unwrap();
        assert_eq!(model.layers.len(), 2);
        assert_eq!(model.input_dim(), 6);
        assert_eq!(model.output_dim(), 3);
        assert!(model.layers[0].relu && !model.layers[1].relu);
        assert_eq!(model.param_count(), (4 + 6) * 2 + 3 * 4);

        let x = gaussian(3, 6, 1.0, &mut g);
        let y = model.forward(&x);
        assert_eq!(y.shape(), (3, 3));
        // Reference: reconstruct layer 0 densely, apply relu chain by hand.
        let w0 = match &model.layers[0].kernel {
            LinearKernel::Factored(f) => matmul(&f.u, &f.vt),
            _ => unreachable!(),
        };
        let mut h = gemm::matvec_batch(&x, &w0);
        for r in 0..h.rows() {
            for v in h.row_mut(r).iter_mut() {
                *v += 0.1;
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        let whead = match &model.layers[1].kernel {
            LinearKernel::Dense(d) => d.w.clone(),
            _ => unreachable!(),
        };
        let mut want = gemm::matvec_batch(&h, &whead);
        for r in 0..want.rows() {
            for v in want.row_mut(r).iter_mut() {
                *v += -0.2;
            }
        }
        assert!(y.sub(&want).max_abs() < 1e-4);
    }

    #[test]
    fn subset_stages_compose_to_the_full_forward() {
        let mut g = GaussianSource::new(9);
        let mut tf = TensorFile::new();
        // 6 → 5 → 4 → 3 chain with biases on the middle layers.
        store_weight(&mut tf, "layers.0", &StoredWeight::Dense(gaussian(5, 6, 1.0, &mut g)));
        tf.insert("layers.0.bias", TensorEntry::from_f32(vec![5], &[0.2; 5]));
        store_weight(&mut tf, "layers.1", &StoredWeight::Dense(gaussian(4, 5, 1.0, &mut g)));
        tf.insert("layers.1.bias", TensorEntry::from_f32(vec![4], &[-0.1; 4]));
        store_weight(&mut tf, "head", &StoredWeight::Dense(gaussian(3, 4, 1.0, &mut g)));

        let full = ModelKernels::load(&tf).unwrap();
        let stage0 =
            ModelKernels::load_subset(&tf, &["layers.0".into(), "layers.1".into()], false)
                .unwrap();
        let stage1 = ModelKernels::load_subset(&tf, &["head".into()], true).unwrap();
        // A mid-chain stage keeps its trailing ReLU; the final stage's
        // head stays a bare affine map.
        assert!(stage0.layers.last().unwrap().relu);
        assert!(!stage1.layers.last().unwrap().relu);

        let x = gaussian(4, 6, 1.0, &mut g);
        let want = full.forward(&x);
        let got = stage1.forward(&stage0.forward(&x));
        assert_eq!(want.shape(), got.shape());
        for (a, b) in want.data().iter().zip(got.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "staged forward must be bit-identical");
        }
    }

    #[test]
    fn quantized_kernel_matches_dequantized_reference() {
        let mut g = GaussianSource::new(12);
        let u = gaussian(9, 4, 1.0, &mut g);
        let vt = gaussian(4, 13, 1.0, &mut g);
        let x = gaussian(5, 13, 1.0, &mut g);
        let (qu, qvt) = (QuantMat::quantize(&u), QuantMat::quantize(&vt));
        let quant = LinearKernel::QuantizedFactored(QuantFactoredLinear {
            u: qu.clone(),
            vt: qvt.clone(),
        });
        // Reference: the same two-GEMM forward over the dequantized f32
        // factors — the quantized kernel differs only in where the row
        // scale is applied, so the results agree to float rounding.
        let reference = LinearKernel::Factored(FactoredLinear {
            u: qu.dequantize(),
            vt: qvt.dequantize(),
        });
        let yq = quant.forward(&x);
        let yr = reference.forward(&x);
        assert_eq!(yq.shape(), (5, 9));
        assert!(yq.sub(&yr).max_abs() < 1e-4, "diff {}", yq.sub(&yr).max_abs());
        assert_eq!(quant.rank(), Some(4));
        assert_eq!(quant.flops_per_sample(), 4 * (9 + 13));
        assert_eq!(quant.param_count(), 9 * 4 + 4 * 13);
    }

    #[test]
    fn quantized_model_serves_end_to_end() {
        let mut g = GaussianSource::new(13);
        let mut tf = TensorFile::new();
        let (a, b) = (gaussian(4, 2, 1.0, &mut g), gaussian(2, 6, 1.0, &mut g));
        crate::io::checkpoint::store_factors(
            &mut tf,
            "layers.0",
            &a,
            &b,
            crate::io::checkpoint::StoreDType::I8,
        );
        tf.insert("layers.0.bias", TensorEntry::from_f32(vec![4], &[0.3; 4]));
        store_weight(&mut tf, "head", &StoredWeight::Dense(gaussian(3, 4, 1.0, &mut g)));

        let model = ModelKernels::load(&tf).unwrap();
        assert!(matches!(model.layers[0].kernel, LinearKernel::QuantizedFactored(_)));
        assert_eq!(model.layers[0].kernel.rank(), Some(2));
        let x = gaussian(3, 6, 1.0, &mut g);
        let y = model.forward(&x);
        assert_eq!(y.shape(), (3, 3));

        // Reference: serve the dequantized factors as a plain f32 model.
        let mut tf_ref = tf.clone();
        let stored = crate::io::checkpoint::load_weight(&tf, "layers.0").unwrap();
        let StoredWeight::QuantizedFactored { a: qa, b: qb } = stored else { unreachable!() };
        store_weight(
            &mut tf_ref,
            "layers.0",
            &StoredWeight::Factored { a: qa.dequantize(), b: qb.dequantize() },
        );
        let want = ModelKernels::load(&tf_ref).unwrap().forward(&x);
        assert!(y.sub(&want).max_abs() < 1e-4, "diff {}", y.sub(&want).max_abs());
    }

    #[test]
    fn unchained_layers_rejected() {
        let mut g = GaussianSource::new(3);
        let mut tf = TensorFile::new();
        store_weight(&mut tf, "layers.0", &StoredWeight::Dense(gaussian(4, 6, 1.0, &mut g)));
        // Next layer consumes 5 features, but the previous emits 4.
        store_weight(&mut tf, "layers.1", &StoredWeight::Dense(gaussian(3, 5, 1.0, &mut g)));
        let err = ModelKernels::load(&tf).unwrap_err();
        assert!(format!("{err:#}").contains("don't chain"));
    }

    #[test]
    fn empty_and_bad_bias_rejected() {
        let tf = TensorFile::new();
        assert!(ModelKernels::load(&tf).is_err());
        let mut g = GaussianSource::new(4);
        let mut tf = TensorFile::new();
        store_weight(&mut tf, "head", &StoredWeight::Dense(gaussian(3, 4, 1.0, &mut g)));
        tf.insert("head.bias", TensorEntry::from_f32(vec![5], &[0.0; 5]));
        let err = ModelKernels::load(&tf).unwrap_err();
        assert!(format!("{err:#}").contains("5 values"));
    }

    /// The obs invariant at its source: timing a layer forward must not
    /// move a single output bit, and the registry sees every call.
    #[test]
    fn instrumented_forward_is_bit_identical_and_counted() {
        let mut g = GaussianSource::new(21);
        let mut tf = TensorFile::new();
        let (a, b) = (gaussian(4, 2, 1.0, &mut g), gaussian(2, 6, 1.0, &mut g));
        store_weight(&mut tf, "layers.0", &StoredWeight::Factored { a, b });
        store_weight(&mut tf, "head", &StoredWeight::Dense(gaussian(3, 4, 1.0, &mut g)));
        let model = ModelKernels::load(&tf).unwrap();
        let x = gaussian(5, 6, 1.0, &mut g);

        let _guard = crate::obs::lock(&crate::obs::TEST_GUARD);
        crate::obs::set_enabled(false);
        let plain = model.forward(&x);
        crate::obs::layers::reset();
        crate::obs::span::reset();
        crate::obs::set_enabled(true);
        let timed = model.forward(&x);
        crate::obs::set_enabled(false);

        for (p, t) in plain.data().iter().zip(timed.data()) {
            assert_eq!(p.to_bits(), t.to_bits(), "instrumentation changed an output bit");
        }
        let snap = crate::obs::layers::snapshot();
        assert_eq!(snap.len(), 2, "both layers must register");
        let head = snap.iter().find(|(n, _)| n == "head").unwrap();
        assert_eq!(head.1.calls, 1);
        assert_eq!(head.1.rows, 5);
        assert_eq!(head.1.flops, 2 * (3 * 4) * 5);
        assert!(crate::obs::span::recorded_total() >= 2);
        crate::obs::layers::reset();
        crate::obs::span::reset();
    }
}
