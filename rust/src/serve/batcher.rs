//! Micro-batching front end: coalesce concurrent requests into one GEMM.
//!
//! Requests enqueue on a channel; a dedicated batcher thread pulls the
//! first request of a batch, then keeps collecting until either
//! `max_batch` inputs are in hand or `max_wait` has elapsed since the
//! batch opened — whichever comes first — and hands the whole batch to a
//! [`BatchExecutor`]. A lone request is therefore answered after at most
//! `max_wait` (flush-on-timeout), while a burst of N concurrent requests
//! collapses into ⌈N/max_batch⌉ executor calls instead of N.
//!
//! The executor is what makes the same batcher serve both deployment
//! shapes: [`LocalExecutor`] runs the batch as one forward pass on the
//! in-process [`WorkerPool`];
//! [`RoutedExecutor`](super::cluster::RoutedExecutor) ships it to a
//! cluster worker over the wire, falling back to local execution when
//! the fleet fails. The batcher never knows the difference.

use super::kernel::ModelKernels;
use super::metrics::ServeMetrics;
use crate::coordinator::pool::WorkerPool;
use crate::tensor::Mat;
use std::sync::atomic::AtomicUsize;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Executes one coalesced batch. Implementations must answer every input
/// row (one output row per input row, in order) or fail the whole batch.
pub trait BatchExecutor: Send + Sync {
    /// Checkpoint label for per-model metrics (the path as submitted).
    fn label(&self) -> &str;
    /// Input feature width the underlying model expects.
    fn input_dim(&self) -> usize;
    /// Run one batch (N×input_dim) to N output rows.
    fn execute(&self, inputs: Mat<f32>) -> Result<Vec<Vec<f32>>, String>;
}

/// In-process execution: one batched forward pass on the shared pool —
/// the single-host path, and the failover target of routed serving.
pub struct LocalExecutor {
    label: String,
    model: Arc<ModelKernels>,
    pool: Arc<WorkerPool>,
}

impl LocalExecutor {
    pub fn new(label: impl Into<String>, model: Arc<ModelKernels>, pool: Arc<WorkerPool>) -> Self {
        LocalExecutor { label: label.into(), model, pool }
    }

    pub fn model(&self) -> &Arc<ModelKernels> {
        &self.model
    }
}

impl BatchExecutor for LocalExecutor {
    fn label(&self) -> &str {
        &self.label
    }

    fn input_dim(&self) -> usize {
        self.model.input_dim()
    }

    fn execute(&self, inputs: Mat<f32>) -> Result<Vec<Vec<f32>>, String> {
        let model = self.model.clone();
        self.pool
            .submit_handle(move || {
                let out = model.forward(&inputs);
                (0..out.rows()).map(|r| out.row(r).to_vec()).collect::<Vec<Vec<f32>>>()
            })
            .wait()
    }
}

/// Coalescing knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Largest batch one executor call serves.
    pub max_batch: usize,
    /// Longest a batch stays open waiting for more requests.
    pub max_wait: Duration,
    /// Queued-request bound: submissions beyond this are rejected
    /// immediately ("server overloaded") instead of buffering without
    /// limit — sustained overload sheds load rather than growing memory
    /// and tail latency forever.
    pub max_queue: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 32, max_wait: Duration::from_millis(2), max_queue: 8192 }
    }
}

/// One queued inference request.
struct Request {
    input: Vec<f32>,
    enqueued: Instant,
    tx: Sender<Result<Vec<f32>, String>>,
}

/// Handle to one in-flight request; [`wait`](Self::wait) blocks for the
/// response.
pub struct PendingResponse {
    rx: Receiver<Result<Vec<f32>, String>>,
}

impl PendingResponse {
    /// Block until the response (or the server's failure message) arrives.
    pub fn wait(self) -> Result<Vec<f32>, String> {
        self.rx.recv().unwrap_or_else(|_| Err("server shut down before responding".into()))
    }
}

/// The micro-batching queue for one loaded model. Dropping the batcher
/// closes the queue; the thread flushes whatever is pending and exits.
pub struct Batcher {
    tx: Option<Sender<Request>>,
    thread: Option<std::thread::JoinHandle<()>>,
    metrics: Arc<ServeMetrics>,
    /// Requests accepted but not yet pulled into a batch (queue gauge;
    /// shared with the batcher thread, which decrements on pull).
    queued: Arc<AtomicUsize>,
    max_queue: usize,
    input_dim: usize,
}

impl Batcher {
    /// Spawn the batcher thread, flushing batches into `executor`.
    pub fn spawn(
        executor: Arc<dyn BatchExecutor>,
        metrics: Arc<ServeMetrics>,
        config: BatcherConfig,
    ) -> Batcher {
        let input_dim = executor.input_dim();
        let (tx, rx) = channel::<Request>();
        let loop_metrics = metrics.clone();
        let queued = Arc::new(AtomicUsize::new(0));
        let loop_queued = queued.clone();
        let thread = std::thread::Builder::new()
            .name("rsic-batcher".into())
            .spawn(move || batch_loop(rx, executor, loop_metrics, loop_queued, config))
            .expect("spawn batcher thread");
        Batcher {
            tx: Some(tx),
            thread: Some(thread),
            metrics,
            queued,
            max_queue: config.max_queue.max(1),
            input_dim,
        }
    }

    /// Convenience for in-process serving: spawn over a [`LocalExecutor`].
    pub fn spawn_local(
        model: Arc<ModelKernels>,
        pool: Arc<WorkerPool>,
        metrics: Arc<ServeMetrics>,
        config: BatcherConfig,
    ) -> Batcher {
        Self::spawn(Arc::new(LocalExecutor::new("local", model, pool)), metrics, config)
    }

    /// Input width this batcher's model expects.
    pub fn input_dim(&self) -> usize {
        self.input_dim
    }

    /// Enqueue one input vector. Wrong-width inputs and submissions past
    /// the `max_queue` bound are rejected immediately (no batch slot
    /// wasted, no unbounded buffering); the error still arrives through
    /// the returned handle so callers have one code path.
    pub fn submit(&self, input: Vec<f32>) -> PendingResponse {
        use std::sync::atomic::Ordering;
        let (tx, rx) = channel();
        if input.len() != self.input_dim {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            let _ = tx.send(Err(format!(
                "input has {} features, model expects {}",
                input.len(),
                self.input_dim
            )));
            return PendingResponse { rx };
        }
        let depth = self.queued.fetch_add(1, Ordering::AcqRel);
        if depth >= self.max_queue {
            self.queued.fetch_sub(1, Ordering::AcqRel);
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            let _ = tx.send(Err(format!("server overloaded: {depth} requests already queued")));
            return PendingResponse { rx };
        }
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let req = Request { input, enqueued: Instant::now(), tx };
        let queue = self.tx.as_ref().expect("batcher queue alive until drop");
        if let Err(send_err) = queue.send(req) {
            self.queued.fetch_sub(1, Ordering::AcqRel);
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            let _ = send_err.0.tx.send(Err("batcher thread is gone".into()));
        }
        PendingResponse { rx }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the queue: the thread drains and exits
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Collect-and-flush loop (one per batcher thread).
fn batch_loop(
    rx: Receiver<Request>,
    executor: Arc<dyn BatchExecutor>,
    metrics: Arc<ServeMetrics>,
    queued: Arc<AtomicUsize>,
    config: BatcherConfig,
) {
    use std::sync::atomic::Ordering;
    let max_batch = config.max_batch.max(1);
    loop {
        // Block for the request that opens the next batch; queue closure
        // (all senders dropped) ends the loop.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return,
        };
        queued.fetch_sub(1, Ordering::AcqRel);
        let mut batch = vec![first];
        let deadline = Instant::now() + config.max_wait;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => {
                    queued.fetch_sub(1, Ordering::AcqRel);
                    batch.push(r);
                }
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        flush(&executor, &metrics, batch);
    }
}

/// Hand one coalesced batch to the executor and scatter the output rows
/// back to their requesters.
fn flush(executor: &Arc<dyn BatchExecutor>, metrics: &ServeMetrics, batch: Vec<Request>) {
    let rows: Vec<&[f32]> = batch.iter().map(|r| r.input.as_slice()).collect();
    let inputs = Mat::from_rows(&rows);
    drop(rows);
    metrics.record_batch(batch.len());
    match executor.execute(inputs) {
        Ok(outputs) if outputs.len() == batch.len() => {
            for (req, out) in batch.into_iter().zip(outputs) {
                metrics.record_latency(executor.label(), req.enqueued.elapsed().as_secs_f64());
                let _ = req.tx.send(Ok(out));
            }
        }
        Ok(outputs) => {
            let msg = format!(
                "executor answered {} rows for a {}-request batch",
                outputs.len(),
                batch.len()
            );
            for req in batch {
                let _ = req.tx.send(Err(msg.clone()));
            }
        }
        Err(msg) => {
            for req in batch {
                let _ = req.tx.send(Err(msg.clone()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::checkpoint::{store_weight, StoredWeight};
    use crate::io::tenz::TensorFile;
    use crate::rng::GaussianSource;
    use crate::tensor::init::gaussian;

    fn tiny_model(d: usize, c: usize) -> Arc<ModelKernels> {
        let mut g = GaussianSource::new(7);
        let mut tf = TensorFile::new();
        store_weight(&mut tf, "head", &StoredWeight::Dense(gaussian(c, d, 1.0, &mut g)));
        Arc::new(ModelKernels::load(&tf).unwrap())
    }

    #[test]
    fn single_request_flushes_on_max_wait() {
        let pool = Arc::new(WorkerPool::new(1, 2));
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = Batcher::spawn_local(
            tiny_model(4, 2),
            pool.clone(),
            metrics.clone(),
            BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_millis(10),
                ..Default::default()
            },
        );
        let y = batcher.submit(vec![1.0; 4]).wait().unwrap();
        assert_eq!(y.len(), 2);
        use std::sync::atomic::Ordering;
        // One lone request ⇒ exactly one batch of occupancy 1, answered
        // without waiting for 63 more inputs that never come.
        assert_eq!(metrics.batches.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.batched_inputs.load(Ordering::Relaxed), 1);
        drop(batcher);
    }

    #[test]
    fn wrong_width_rejected_immediately() {
        let pool = Arc::new(WorkerPool::new(1, 2));
        let metrics = Arc::new(ServeMetrics::new());
        let batcher =
            Batcher::spawn_local(tiny_model(4, 2), pool.clone(), metrics.clone(), Default::default());
        let err = batcher.submit(vec![1.0; 3]).wait().unwrap_err();
        assert!(err.contains("3 features"));
        use std::sync::atomic::Ordering;
        assert_eq!(metrics.rejected.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.batches.load(Ordering::Relaxed), 0);
        drop(batcher);
    }

    #[test]
    fn overload_sheds_requests_once_queue_is_full() {
        use std::sync::atomic::Ordering;
        let pool = Arc::new(WorkerPool::new(1, 1));
        let metrics = Arc::new(ServeMetrics::new());
        // Saturate the single worker so the batcher's flush blocks behind
        // it and the queue actually backs up.
        let (block_tx, block_rx) = std::sync::mpsc::channel::<()>();
        let blocker = pool.submit_handle(move || {
            let _ = block_rx.recv();
            0usize
        });
        let batcher = Batcher::spawn_local(
            tiny_model(3, 2),
            pool.clone(),
            metrics.clone(),
            BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1), max_queue: 3 },
        );
        // First request: pulled into a batch whose flush is stuck behind
        // the blocker. record_batch fires before the flush blocks, so
        // batches==1 means the request has left the queue.
        let first = batcher.submit(vec![0.0; 3]);
        while metrics.batches.load(Ordering::Relaxed) == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        // Fill the queue to its bound, then watch the shed.
        let queued: Vec<_> = (0..3).map(|_| batcher.submit(vec![0.0; 3])).collect();
        let shed = batcher.submit(vec![0.0; 3]);
        assert!(shed.wait().unwrap_err().contains("overloaded"));
        assert_eq!(metrics.rejected.load(Ordering::Relaxed), 1);
        // Unblock: everything accepted is still answered.
        block_tx.send(()).unwrap();
        assert_eq!(blocker.wait().unwrap(), 0);
        assert_eq!(first.wait().unwrap().len(), 2);
        for p in queued {
            assert_eq!(p.wait().unwrap().len(), 2);
        }
        drop(batcher);
    }

    #[test]
    fn drop_flushes_pending_requests() {
        let pool = Arc::new(WorkerPool::new(1, 2));
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = Batcher::spawn_local(
            tiny_model(3, 2),
            pool.clone(),
            metrics.clone(),
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(50),
                ..Default::default()
            },
        );
        let pending: Vec<PendingResponse> =
            (0..5).map(|i| batcher.submit(vec![i as f32; 3])).collect();
        drop(batcher); // closes the queue; pending work must still answer
        for p in pending {
            assert_eq!(p.wait().unwrap().len(), 2);
        }
    }

    /// An executor that answers the wrong number of rows fails the whole
    /// batch with a diagnostic instead of scattering misaligned outputs.
    #[test]
    fn row_count_mismatch_fails_the_batch() {
        struct Short;
        impl BatchExecutor for Short {
            fn label(&self) -> &str {
                "short"
            }
            fn input_dim(&self) -> usize {
                2
            }
            fn execute(&self, _inputs: Mat<f32>) -> Result<Vec<Vec<f32>>, String> {
                Ok(vec![])
            }
        }
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = Batcher::spawn(Arc::new(Short), metrics, Default::default());
        let err = batcher.submit(vec![0.0; 2]).wait().unwrap_err();
        assert!(err.contains("0 rows"), "{err}");
        drop(batcher);
    }
}
