//! Micro-batching front end: coalesce concurrent requests into one GEMM,
//! with per-tenant fair queueing and admission control.
//!
//! Requests enqueue into per-tenant FIFO queues behind one mutex; a
//! dedicated batcher thread waits for work, then keeps collecting until
//! either `max_batch` inputs are queued or `max_wait` has elapsed since
//! the batch opened — whichever comes first — drains the queues via
//! **deficit round-robin** (each tenant earns `weight` slots per round,
//! so a flooding tenant cannot starve the others), and hands the whole
//! batch to a [`BatchExecutor`]. A lone request is therefore answered
//! after at most `max_wait` (flush-on-timeout), while a burst of N
//! concurrent requests collapses into ⌈N/max_batch⌉ executor calls
//! instead of N.
//!
//! Admission control happens at [`Batcher::try_submit`]: a submission is
//! bounced (the input handed back, no response channel burned) when the
//! global `max_queue` bound or the tenant's queue quota is hit — the
//! caller decides whether that becomes a shed or a degrade-reroute to a
//! sibling checkpoint. Admitted requests can still be shed at drain time
//! when they out-waited their tenant's deadline; both paths surface as
//! [`RequestError::Shed`], distinguishable from genuine model failures
//! ([`RequestError::Failed`]).
//!
//! The executor is what makes the same batcher serve both deployment
//! shapes: [`LocalExecutor`] runs the batch as one forward pass on the
//! in-process [`WorkerPool`];
//! [`RoutedExecutor`](super::cluster::RoutedExecutor) ships it to a
//! cluster worker over the wire, falling back to local execution when
//! the fleet fails. The batcher never knows the difference.

use super::kernel::ModelKernels;
use super::metrics::ServeMetrics;
use crate::coordinator::pool::WorkerPool;
use crate::util::lock_recover;
use crate::tensor::Mat;
use std::collections::{BTreeMap, VecDeque};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Tenant name used when callers don't speak tenants ([`Batcher::submit`]).
pub const DEFAULT_TENANT: &str = "default";

/// Executes one coalesced batch. Implementations must answer every input
/// row (one output row per input row, in order) or fail the whole batch.
pub trait BatchExecutor: Send + Sync {
    /// Checkpoint label for per-model metrics (the path as submitted).
    fn label(&self) -> &str;
    /// Input feature width the underlying model expects.
    fn input_dim(&self) -> usize;
    /// Run one batch (N×input_dim) to N output rows.
    fn execute(&self, inputs: Mat<f32>) -> Result<Vec<Vec<f32>>, String>;
}

/// In-process execution: one batched forward pass on the shared pool —
/// the single-host path, and the failover target of routed serving.
pub struct LocalExecutor {
    label: String,
    model: Arc<ModelKernels>,
    pool: Arc<WorkerPool>,
}

impl LocalExecutor {
    pub fn new(label: impl Into<String>, model: Arc<ModelKernels>, pool: Arc<WorkerPool>) -> Self {
        LocalExecutor { label: label.into(), model, pool }
    }

    pub fn model(&self) -> &Arc<ModelKernels> {
        &self.model
    }
}

impl BatchExecutor for LocalExecutor {
    fn label(&self) -> &str {
        &self.label
    }

    fn input_dim(&self) -> usize {
        self.model.input_dim()
    }

    fn execute(&self, inputs: Mat<f32>) -> Result<Vec<Vec<f32>>, String> {
        let model = self.model.clone();
        self.pool
            .submit_handle(move || {
                let out = model.forward(&inputs);
                (0..out.rows()).map(|r| out.row(r).to_vec()).collect::<Vec<Vec<f32>>>()
            })
            .wait()
    }
}

/// Coalescing and admission knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Largest batch one executor call serves.
    pub max_batch: usize,
    /// Longest a batch stays open waiting for more requests.
    pub max_wait: Duration,
    /// Queued-request bound across all tenants: submissions beyond this
    /// are bounced ("server overloaded") instead of buffering without
    /// limit — sustained overload sheds load rather than growing memory
    /// and tail latency forever.
    pub max_queue: usize,
    /// Default per-tenant queue quota applied when a [`TenantPolicy`]
    /// doesn't set its own. `None` = only `max_queue` bounds a tenant.
    pub tenant_quota: Option<usize>,
    /// Default queue deadline: admitted requests still waiting past it
    /// are shed at drain time instead of executing uselessly late.
    pub deadline: Option<Duration>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            max_queue: 8192,
            tenant_quota: None,
            deadline: None,
        }
    }
}

/// Per-tenant admission policy: how much queue a tenant may hold, how
/// long its requests stay worth answering, what weight its queue drains
/// at, and where to degrade when it overflows.
#[derive(Debug, Clone)]
pub struct TenantPolicy {
    /// Tenant name — keys the per-tenant queue and metric rows.
    pub name: Arc<str>,
    /// Deficit-round-robin weight: slots earned per drain round relative
    /// to other tenants (minimum 1).
    pub weight: u32,
    /// Queued-request bound for this tenant alone; falls back to
    /// [`BatcherConfig::tenant_quota`] when `None`.
    pub queue_quota: Option<usize>,
    /// Queue deadline (the latency SLO): admitted requests waiting
    /// longer are shed at drain time. Falls back to
    /// [`BatcherConfig::deadline`].
    pub deadline: Option<Duration>,
    /// Sibling checkpoint (lower rank / i8) the admission controller
    /// reroutes to instead of shedding — the paper's ‖Δy‖ ≤
    /// ‖W−UVᵀ‖₂‖x‖₂ bound prices exactly what that substitution costs.
    pub degrade_to: Option<PathBuf>,
}

impl TenantPolicy {
    pub fn named(name: &str) -> TenantPolicy {
        TenantPolicy {
            name: Arc::from(name),
            weight: 1,
            queue_quota: None,
            deadline: None,
            degrade_to: None,
        }
    }
}

impl Default for TenantPolicy {
    fn default() -> Self {
        TenantPolicy::named(DEFAULT_TENANT)
    }
}

/// Why a request came back without an output vector: the server *chose*
/// not to serve it (`Shed` — admission control or deadline), or it tried
/// and couldn't (`Failed` — bad input width, executor error, shutdown).
/// Throughput accounting needs the distinction: shed is load the policy
/// declined, failure is load the system broke on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RequestError {
    Shed(String),
    Failed(String),
}

impl RequestError {
    pub fn is_shed(&self) -> bool {
        matches!(self, RequestError::Shed(_))
    }

    pub fn message(&self) -> &str {
        match self {
            RequestError::Shed(m) | RequestError::Failed(m) => m,
        }
    }
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.message())
    }
}

/// One queued inference request.
struct Request {
    input: Vec<f32>,
    tenant: Arc<str>,
    enqueued: Instant,
    /// Drain-time shed point (tenant deadline), when configured.
    expires: Option<Instant>,
    tx: Sender<Result<Vec<f32>, RequestError>>,
}

/// Handle to one in-flight request; [`wait`](Self::wait) blocks for the
/// response.
pub struct PendingResponse {
    rx: Receiver<Result<Vec<f32>, RequestError>>,
}

impl PendingResponse {
    /// A handle that is already resolved to `err` — how admission
    /// decisions surface through the same code path as real responses.
    pub fn immediate_error(err: RequestError) -> PendingResponse {
        let (tx, rx) = channel();
        let _ = tx.send(Err(err));
        PendingResponse { rx }
    }

    /// Block until the response (or the server's failure message) arrives.
    pub fn wait(self) -> Result<Vec<f32>, String> {
        self.wait_outcome().map_err(|e| e.message().to_string())
    }

    /// Like [`wait`](Self::wait), but keeps the shed/failed distinction.
    pub fn wait_outcome(self) -> Result<Vec<f32>, RequestError> {
        self.rx
            .recv()
            .unwrap_or_else(|_| Err(RequestError::Failed("server shut down before responding".into())))
    }

    /// Non-blocking poll: `None` while the request is still in flight.
    pub fn try_wait(&self) -> Option<Result<Vec<f32>, RequestError>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                Some(Err(RequestError::Failed("server shut down before responding".into())))
            }
        }
    }
}

/// One tenant's FIFO plus its drain weight.
struct TenantQueue {
    weight: u32,
    deque: VecDeque<Request>,
}

/// Everything behind the queue mutex. `BTreeMap` (not `HashMap`) so the
/// drain visits tenants in a deterministic order — fairness proofs in the
/// tests depend on the round-robin order being reproducible.
struct QueueState {
    queues: BTreeMap<Arc<str>, TenantQueue>,
    total: usize,
    closed: bool,
}

struct Shared {
    state: Mutex<QueueState>,
    arrived: Condvar,
}

/// The micro-batching queue for one loaded model. Dropping the batcher
/// closes the queue; the thread flushes whatever is pending and exits.
pub struct Batcher {
    shared: Arc<Shared>,
    thread: Option<std::thread::JoinHandle<()>>,
    metrics: Arc<ServeMetrics>,
    config: BatcherConfig,
    input_dim: usize,
    default_policy: TenantPolicy,
}

impl Batcher {
    /// Spawn the batcher thread, flushing batches into `executor`.
    pub fn spawn(
        executor: Arc<dyn BatchExecutor>,
        metrics: Arc<ServeMetrics>,
        config: BatcherConfig,
    ) -> Batcher {
        let input_dim = executor.input_dim();
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState { queues: BTreeMap::new(), total: 0, closed: false }),
            arrived: Condvar::new(),
        });
        let loop_shared = shared.clone();
        let loop_metrics = metrics.clone();
        let thread = std::thread::Builder::new()
            .name("rsic-batcher".into())
            .spawn(move || batch_loop(loop_shared, executor, loop_metrics, config))
            .expect("spawn batcher thread");
        Batcher {
            shared,
            thread: Some(thread),
            metrics,
            config,
            input_dim,
            default_policy: TenantPolicy::default(),
        }
    }

    /// Convenience for in-process serving: spawn over a [`LocalExecutor`].
    pub fn spawn_local(
        model: Arc<ModelKernels>,
        pool: Arc<WorkerPool>,
        metrics: Arc<ServeMetrics>,
        config: BatcherConfig,
    ) -> Batcher {
        Self::spawn(Arc::new(LocalExecutor::new("local", model, pool)), metrics, config)
    }

    /// Input width this batcher's model expects.
    pub fn input_dim(&self) -> usize {
        self.input_dim
    }

    /// Queued requests right now, across all tenants (tests/diagnostics).
    pub fn queue_depth(&self) -> usize {
        lock_recover(&self.shared.state).total
    }

    /// Enqueue one input under `policy`. `Err(input)` hands the vector
    /// back when admission control bounces it (global `max_queue` or the
    /// tenant quota) — the caller decides shed vs degrade and no response
    /// channel is burned. Wrong-width inputs and closed queues *are*
    /// answered (`Ok` with a failed handle): those aren't load decisions.
    pub fn try_submit(
        &self,
        policy: &TenantPolicy,
        input: Vec<f32>,
    ) -> Result<PendingResponse, Vec<f32>> {
        use std::sync::atomic::Ordering;
        if input.len() != self.input_dim {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Ok(PendingResponse::immediate_error(RequestError::Failed(format!(
                "input has {} features, model expects {}",
                input.len(),
                self.input_dim
            ))));
        }
        let quota = policy.queue_quota.or(self.config.tenant_quota);
        let expires = policy
            .deadline
            .or(self.config.deadline)
            .map(|d| Instant::now() + d);
        {
            let mut st = lock_recover(&self.shared.state);
            if st.closed {
                drop(st);
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Ok(PendingResponse::immediate_error(RequestError::Failed(
                    "batcher thread is gone".into(),
                )));
            }
            if st.total >= self.config.max_queue.max(1) {
                return Err(input);
            }
            if let Some(quota) = quota {
                // quota 0 = no queue at all: every request bounces to the
                // caller's degrade/shed decision.
                let depth = st.queues.get(&*policy.name).map_or(0, |q| q.deque.len());
                if depth >= quota {
                    return Err(input);
                }
            }
            let (tx, rx) = channel();
            let req =
                Request { input, tenant: policy.name.clone(), enqueued: Instant::now(), expires, tx };
            let entry = st
                .queues
                .entry(policy.name.clone())
                .or_insert_with(|| TenantQueue { weight: 1, deque: VecDeque::new() });
            entry.weight = policy.weight.max(1);
            entry.deque.push_back(req);
            st.total += 1;
            drop(st);
            self.metrics.requests.fetch_add(1, Ordering::Relaxed);
            self.shared.arrived.notify_one();
            Ok(PendingResponse { rx })
        }
    }

    /// Enqueue one input vector under the default tenant. Admission
    /// bounces become an immediate shed here (single-tenant callers have
    /// no degrade ladder); the error still arrives through the returned
    /// handle so callers have one code path.
    pub fn submit(&self, input: Vec<f32>) -> PendingResponse {
        use std::sync::atomic::Ordering;
        match self.try_submit(&self.default_policy, input) {
            Ok(pending) => pending,
            Err(_input) => {
                let depth = lock_recover(&self.shared.state).total;
                self.metrics.shed.fetch_add(1, Ordering::Relaxed);
                PendingResponse::immediate_error(RequestError::Shed(format!(
                    "server overloaded: {depth} requests already queued"
                )))
            }
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        {
            let mut st = lock_recover(&self.shared.state);
            st.closed = true; // close the queue: the thread drains and exits
        }
        self.shared.arrived.notify_all();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Pull up to `max_batch` requests out of the tenant queues by deficit
/// round-robin: every non-empty tenant earns `weight` slots per round, a
/// tenant whose queue empties forfeits leftover credit. With per-request
/// cost 1 and quantum ≥ 1 every round makes progress, and over time each
/// backlogged tenant's share of batch slots converges to its weight share
/// — the flooding tenant queues behind itself, not behind everyone.
fn drain_drr(
    state: &mut QueueState,
    deficits: &mut BTreeMap<Arc<str>, u64>,
    max_batch: usize,
) -> Vec<Request> {
    let mut out = Vec::with_capacity(max_batch.min(state.total));
    while out.len() < max_batch && state.total > 0 {
        let backlogged: Vec<Arc<str>> = state
            .queues
            .iter()
            .filter(|(_, q)| !q.deque.is_empty())
            .map(|(name, _)| name.clone())
            .collect();
        for name in backlogged {
            if out.len() >= max_batch {
                break;
            }
            let q = state.queues.get_mut(&name).expect("backlogged tenant present");
            if q.deque.is_empty() {
                continue;
            }
            let credit = deficits.entry(name.clone()).or_insert(0);
            *credit += u64::from(q.weight.max(1));
            while *credit > 0 && out.len() < max_batch {
                match q.deque.pop_front() {
                    Some(req) => {
                        *credit -= 1;
                        state.total -= 1;
                        out.push(req);
                    }
                    None => break,
                }
            }
            if q.deque.is_empty() {
                deficits.remove(&name);
            }
        }
    }
    out
}

/// Collect-and-flush loop (one per batcher thread).
fn batch_loop(
    shared: Arc<Shared>,
    executor: Arc<dyn BatchExecutor>,
    metrics: Arc<ServeMetrics>,
    config: BatcherConfig,
) {
    use std::sync::atomic::Ordering;
    let max_batch = config.max_batch.max(1);
    // DRR credit persists across batches so weight shares hold over time,
    // not just within one drain.
    let mut deficits: BTreeMap<Arc<str>, u64> = BTreeMap::new();
    loop {
        let batch = {
            let mut st = lock_recover(&shared.state);
            // Block for the request that opens the next batch; closure
            // with an empty queue ends the loop.
            while st.total == 0 {
                if st.closed {
                    return;
                }
                st = shared.arrived.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner);
            }
            // Keep the batch open (releasing the lock while waiting)
            // until it fills or `max_wait` elapses; closure flushes
            // whatever is pending immediately.
            let deadline = Instant::now() + config.max_wait;
            while st.total < max_batch && !st.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _) = shared
                    .arrived
                    .wait_timeout(st, deadline - now)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
                st = guard;
            }
            drain_drr(&mut st, &mut deficits, max_batch)
        };
        // Deadline shed happens at drain time, outside the lock: requests
        // that out-waited their tenant's SLO are answered with a shed
        // error instead of burning a batch slot on a uselessly late reply.
        let now = Instant::now();
        let mut live = Vec::with_capacity(batch.len());
        for req in batch {
            match req.expires {
                Some(t) if now > t => {
                    metrics.shed.fetch_add(1, Ordering::Relaxed);
                    metrics.tenant_deadline_shed(&req.tenant);
                    let waited_ms = req.enqueued.elapsed().as_secs_f64() * 1e3;
                    if crate::obs::enabled() {
                        crate::obs::recorder::record(
                            crate::obs::recorder::EventKind::DeadlineShed,
                            format!("tenant={} waited_ms={waited_ms:.1}", req.tenant),
                        );
                    }
                    let _ = req.tx.send(Err(RequestError::Shed(format!(
                        "deadline exceeded: request waited {waited_ms:.1} ms in queue"
                    ))));
                }
                _ => live.push(req),
            }
        }
        if !live.is_empty() {
            flush(&executor, &metrics, live);
        }
    }
}

/// Hand one coalesced batch to the executor and scatter the output rows
/// back to their requesters.
fn flush(executor: &Arc<dyn BatchExecutor>, metrics: &ServeMetrics, batch: Vec<Request>) {
    use crate::obs::span::ArgVal;
    let rows: Vec<&[f32]> = batch.iter().map(|r| r.input.as_slice()).collect();
    let inputs = Mat::from_rows(&rows);
    drop(rows);
    metrics.record_batch(batch.len());
    // Queue-wait span: oldest enqueue → the moment the batch leaves for
    // the executor. Recorded before execution so the span measures wait,
    // not wait + compute.
    if crate::obs::enabled() {
        if let Some(oldest) = batch.iter().map(|r| r.enqueued).min() {
            crate::obs::span::record(
                "queue_wait",
                oldest,
                vec![("rows", ArgVal::U64(batch.len() as u64))],
            );
        }
    }
    let t_exec = crate::obs::now_if_enabled();
    let result = executor.execute(inputs);
    if let Some(t0) = t_exec {
        crate::obs::span::record(
            "execute",
            t0,
            vec![
                ("model", ArgVal::Str(executor.label().to_string())),
                ("rows", ArgVal::U64(batch.len() as u64)),
                ("ok", ArgVal::U64(u64::from(result.is_ok()))),
            ],
        );
    }
    match result {
        Ok(outputs) if outputs.len() == batch.len() => {
            for (req, out) in batch.into_iter().zip(outputs) {
                let secs = req.enqueued.elapsed().as_secs_f64();
                metrics.record_latency(executor.label(), secs);
                if req.tenant.as_ref() != DEFAULT_TENANT {
                    metrics.record_tenant_latency(&req.tenant, secs);
                }
                let _ = req.tx.send(Ok(out));
            }
        }
        Ok(outputs) => {
            let msg = format!(
                "executor answered {} rows for a {}-request batch",
                outputs.len(),
                batch.len()
            );
            for req in batch {
                let _ = req.tx.send(Err(RequestError::Failed(msg.clone())));
            }
        }
        Err(msg) => {
            for req in batch {
                let _ = req.tx.send(Err(RequestError::Failed(msg.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::checkpoint::{store_weight, StoredWeight};
    use crate::io::tenz::TensorFile;
    use crate::rng::GaussianSource;
    use crate::tensor::init::gaussian;
    use std::sync::atomic::{AtomicBool, Ordering};

    fn tiny_model(d: usize, c: usize) -> Arc<ModelKernels> {
        let mut g = GaussianSource::new(7);
        let mut tf = TensorFile::new();
        store_weight(&mut tf, "head", &StoredWeight::Dense(gaussian(c, d, 1.0, &mut g)));
        Arc::new(ModelKernels::load(&tf).unwrap())
    }

    #[test]
    fn single_request_flushes_on_max_wait() {
        let pool = Arc::new(WorkerPool::new(1, 2));
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = Batcher::spawn_local(
            tiny_model(4, 2),
            pool.clone(),
            metrics.clone(),
            BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_millis(10),
                ..Default::default()
            },
        );
        let y = batcher.submit(vec![1.0; 4]).wait().unwrap();
        assert_eq!(y.len(), 2);
        // One lone request ⇒ exactly one batch of occupancy 1, answered
        // without waiting for 63 more inputs that never come.
        assert_eq!(metrics.batches.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.batched_inputs.load(Ordering::Relaxed), 1);
        drop(batcher);
    }

    #[test]
    fn wrong_width_rejected_immediately() {
        let pool = Arc::new(WorkerPool::new(1, 2));
        let metrics = Arc::new(ServeMetrics::new());
        let batcher =
            Batcher::spawn_local(tiny_model(4, 2), pool.clone(), metrics.clone(), Default::default());
        let err = batcher.submit(vec![1.0; 3]).wait().unwrap_err();
        assert!(err.contains("3 features"));
        assert_eq!(metrics.rejected.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.batches.load(Ordering::Relaxed), 0);
        drop(batcher);
    }

    #[test]
    fn overload_sheds_requests_once_queue_is_full() {
        let pool = Arc::new(WorkerPool::new(1, 1));
        let metrics = Arc::new(ServeMetrics::new());
        // Saturate the single worker so the batcher's flush blocks behind
        // it and the queue actually backs up.
        let (block_tx, block_rx) = std::sync::mpsc::channel::<()>();
        let blocker = pool.submit_handle(move || {
            let _ = block_rx.recv();
            0usize
        });
        let batcher = Batcher::spawn_local(
            tiny_model(3, 2),
            pool.clone(),
            metrics.clone(),
            BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                max_queue: 3,
                ..Default::default()
            },
        );
        // First request: pulled into a batch whose flush is stuck behind
        // the blocker. record_batch fires before the flush blocks, so
        // batches==1 means the request has left the queue.
        let first = batcher.submit(vec![0.0; 3]);
        while metrics.batches.load(Ordering::Relaxed) == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        // Fill the queue to its bound, then watch the shed.
        let queued: Vec<_> = (0..3).map(|_| batcher.submit(vec![0.0; 3])).collect();
        let shed = batcher.submit(vec![0.0; 3]);
        match shed.wait_outcome().unwrap_err() {
            RequestError::Shed(msg) => assert!(msg.contains("overloaded"), "{msg}"),
            other => panic!("expected a shed, got {other:?}"),
        }
        assert_eq!(metrics.shed.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.rejected.load(Ordering::Relaxed), 0);
        // Unblock: everything accepted is still answered.
        block_tx.send(()).unwrap();
        assert_eq!(blocker.wait().unwrap(), 0);
        assert_eq!(first.wait().unwrap().len(), 2);
        for p in queued {
            assert_eq!(p.wait().unwrap().len(), 2);
        }
        drop(batcher);
    }

    #[test]
    fn drop_flushes_pending_requests() {
        let pool = Arc::new(WorkerPool::new(1, 2));
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = Batcher::spawn_local(
            tiny_model(3, 2),
            pool.clone(),
            metrics.clone(),
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(50),
                ..Default::default()
            },
        );
        let pending: Vec<PendingResponse> =
            (0..5).map(|i| batcher.submit(vec![i as f32; 3])).collect();
        drop(batcher); // closes the queue; pending work must still answer
        for p in pending {
            assert_eq!(p.wait().unwrap().len(), 2);
        }
    }

    /// An executor that answers the wrong number of rows fails the whole
    /// batch with a diagnostic instead of scattering misaligned outputs.
    #[test]
    fn row_count_mismatch_fails_the_batch() {
        struct Short;
        impl BatchExecutor for Short {
            fn label(&self) -> &str {
                "short"
            }
            fn input_dim(&self) -> usize {
                2
            }
            fn execute(&self, _inputs: Mat<f32>) -> Result<Vec<Vec<f32>>, String> {
                Ok(vec![])
            }
        }
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = Batcher::spawn(Arc::new(Short), metrics, Default::default());
        let err = batcher.submit(vec![0.0; 2]).wait().unwrap_err();
        assert!(err.contains("0 rows"), "{err}");
        drop(batcher);
    }

    /// Echo executor whose *first* call blocks until released — lets a
    /// test stack the queues deterministically, then observe exactly how
    /// the drain orders them.
    struct GatedEcho {
        dim: usize,
        entered: AtomicBool,
        released: AtomicBool,
        release: Mutex<Receiver<()>>,
        calls: Mutex<Vec<Vec<f32>>>,
    }

    impl GatedEcho {
        fn new(dim: usize) -> (Arc<GatedEcho>, Sender<()>) {
            let (tx, rx) = channel();
            let gate = Arc::new(GatedEcho {
                dim,
                entered: AtomicBool::new(false),
                released: AtomicBool::new(false),
                release: Mutex::new(rx),
                calls: Mutex::new(Vec::new()),
            });
            (gate, tx)
        }
    }

    impl BatchExecutor for GatedEcho {
        fn label(&self) -> &str {
            "gated-echo"
        }
        fn input_dim(&self) -> usize {
            self.dim
        }
        fn execute(&self, inputs: Mat<f32>) -> Result<Vec<Vec<f32>>, String> {
            if !self.released.swap(true, Ordering::SeqCst) {
                self.entered.store(true, Ordering::SeqCst);
                let _ = self.release.lock().unwrap().recv();
            }
            let tags: Vec<f32> = (0..inputs.rows()).map(|r| inputs.row(r)[0]).collect();
            self.calls.lock().unwrap().push(tags);
            Ok((0..inputs.rows()).map(|r| inputs.row(r).to_vec()).collect())
        }
    }

    /// Deficit round-robin with weights 2:1 — tenant `a` flooding the
    /// queue still drains interleaved at a 2:1 slot ratio with `b`, not
    /// FIFO (which would empty all of `a` first).
    #[test]
    fn drain_is_weighted_round_robin_across_tenants() {
        let (gate, release) = GatedEcho::new(2);
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = Batcher::spawn(
            gate.clone(),
            metrics,
            BatcherConfig { max_batch: 3, max_wait: Duration::from_millis(1), ..Default::default() },
        );
        let mut pol_a = TenantPolicy::named("a");
        pol_a.weight = 2;
        let pol_b = TenantPolicy::named("b");
        // Park the batcher thread inside the first (dummy) flush so the
        // queues below stack up untouched.
        let dummy = batcher.try_submit(&pol_a, vec![0.0; 2]).unwrap();
        while !gate.entered.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        let mut pending = Vec::new();
        for i in 0..4 {
            pending.push(batcher.try_submit(&pol_a, vec![1.0 + i as f32; 2]).unwrap());
        }
        for i in 0..2 {
            pending.push(batcher.try_submit(&pol_b, vec![101.0 + i as f32; 2]).unwrap());
        }
        release.send(()).unwrap();
        assert_eq!(dummy.wait().unwrap().len(), 2);
        for p in pending {
            assert_eq!(p.wait().unwrap().len(), 2);
        }
        let calls = gate.calls.lock().unwrap().clone();
        // Call 0 is the dummy; with max_batch=3 and weights a=2, b=1 the
        // six queued requests drain as [a,a,b] [a,a,b].
        assert_eq!(calls.len(), 3, "{calls:?}");
        assert_eq!(calls[1], vec![1.0, 2.0, 101.0], "{calls:?}");
        assert_eq!(calls[2], vec![3.0, 4.0, 102.0], "{calls:?}");
        drop(batcher);
    }

    /// A tenant quota bounces only the over-quota tenant; the global
    /// queue and other tenants keep admitting.
    #[test]
    fn tenant_quota_bounces_only_that_tenant() {
        let (gate, release) = GatedEcho::new(2);
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = Batcher::spawn(
            gate.clone(),
            metrics,
            BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1), ..Default::default() },
        );
        let mut pol_a = TenantPolicy::named("a");
        pol_a.queue_quota = Some(2);
        let pol_b = TenantPolicy::named("b");
        let dummy = batcher.try_submit(&pol_b, vec![0.0; 2]).unwrap();
        while !gate.entered.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        let a1 = batcher.try_submit(&pol_a, vec![1.0; 2]).unwrap();
        let a2 = batcher.try_submit(&pol_a, vec![2.0; 2]).unwrap();
        // Third `a` hits the quota and hands the input back untouched…
        let bounced = batcher.try_submit(&pol_a, vec![3.0; 2]);
        assert_eq!(bounced.unwrap_err(), vec![3.0; 2]);
        // …while `b` still gets in.
        let b1 = batcher.try_submit(&pol_b, vec![4.0; 2]).unwrap();
        release.send(()).unwrap();
        for p in [dummy, a1, a2, b1] {
            assert!(p.wait().is_ok());
        }
        drop(batcher);
    }

    /// Requests that out-wait their tenant deadline are shed at drain
    /// time with a `Shed` error, not executed uselessly late.
    #[test]
    fn stale_requests_are_shed_at_the_deadline() {
        let (gate, release) = GatedEcho::new(2);
        let metrics = Arc::new(ServeMetrics::new());
        let batcher = Batcher::spawn(
            gate.clone(),
            metrics.clone(),
            BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1), ..Default::default() },
        );
        let mut pol = TenantPolicy::named("slo");
        pol.deadline = Some(Duration::from_millis(5));
        let dummy = batcher.try_submit(&TenantPolicy::default(), vec![0.0; 2]).unwrap();
        while !gate.entered.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        let stale = batcher.try_submit(&pol, vec![1.0; 2]).unwrap();
        // Hold the flush well past the 5 ms deadline before releasing.
        std::thread::sleep(Duration::from_millis(30));
        release.send(()).unwrap();
        assert!(dummy.wait().is_ok());
        match stale.wait_outcome().unwrap_err() {
            RequestError::Shed(msg) => assert!(msg.contains("deadline"), "{msg}"),
            other => panic!("expected a deadline shed, got {other:?}"),
        }
        assert_eq!(metrics.shed.load(Ordering::Relaxed), 1);
        drop(batcher);
    }
}
