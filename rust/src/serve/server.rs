//! The serving engine: model cache + per-model batchers over one pool.
//!
//! One [`Server`] owns one persistent [`WorkerPool`] (the same pool type
//! the compression pipeline runs on), an LRU [`ModelCache`] keyed by
//! checkpoint path + per-file mtime snapshot (single `.tenz` containers
//! and sharded `.toml` manifests alike), and one [`Batcher`] per cached
//! model. Requests
//! against any number of checkpoints share the process: the first request
//! for a checkpoint loads and caches its kernels and spawns its batcher;
//! subsequent requests coalesce into batched GEMM passes.

use super::batcher::{BatchExecutor, Batcher, BatcherConfig, LocalExecutor, PendingResponse};
use super::cache::{ModelCache, ModelKey};
use super::cluster::{RoutedExecutor, Router};
use super::kernel::ModelKernels;
use super::metrics::ServeMetrics;
use crate::coordinator::pool::WorkerPool;
use anyhow::Result;
use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Server construction options (the `rsic serve` CLI flags).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Largest coalesced batch per GEMM pass.
    pub max_batch: usize,
    /// Longest a batch waits for more requests before flushing.
    pub max_wait: Duration,
    /// Worker threads executing batched forward passes.
    pub workers: usize,
    /// Bounded job-queue depth of the pool.
    pub queue_depth: usize,
    /// Per-model queued-request bound: submissions beyond it are shed
    /// ("server overloaded") instead of buffering without limit.
    pub max_queue: usize,
    /// Models kept resident in the LRU cache.
    pub cache_capacity: usize,
    /// Run the checkpoint integrity pass (`verify_hashes` on sharded
    /// checkpoints, a full structural read on single `.tenz`) at every
    /// model load, before any traffic is answered from it.
    pub verify: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            workers: crate::util::default_threads(),
            queue_depth: 16,
            max_queue: 8192,
            cache_capacity: 4,
            verify: false,
        }
    }
}

/// A traffic-serving engine over compressed (or dense) checkpoints.
pub struct Server {
    // Declared before `pool`: batchers join their threads on drop while
    // the pool is still accepting the final flush jobs.
    batchers: Mutex<HashMap<ModelKey, Arc<Batcher>>>,
    pool: Arc<WorkerPool>,
    cache: Arc<ModelCache>,
    metrics: Arc<ServeMetrics>,
    config: ServeConfig,
    /// When set, batches for checkpoints the router's plan covers are
    /// shipped to cluster workers (with local failover); everything else
    /// executes in-process as before.
    router: Option<Arc<Router>>,
}

impl Server {
    pub fn new(config: ServeConfig) -> Server {
        Self::with_router(config, None)
    }

    /// A server whose micro-batcher drains into a cluster [`Router`] for
    /// the checkpoint the router's plan covers. Models are still loaded
    /// (and cached) locally — that is the failover target.
    pub fn with_router(config: ServeConfig, router: Option<Arc<Router>>) -> Server {
        Server {
            batchers: Mutex::new(HashMap::new()),
            pool: Arc::new(WorkerPool::new(config.workers, config.queue_depth)),
            cache: Arc::new(ModelCache::with_verify(config.cache_capacity, config.verify)),
            metrics: Arc::new(ServeMetrics::new()),
            config,
            router,
        }
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    pub fn cache(&self) -> &ModelCache {
        &self.cache
    }

    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// Load (or fetch from cache) the kernels for a checkpoint — also the
    /// warm-up/validation entry point: a bad checkpoint fails here, before
    /// any traffic is pointed at it.
    pub fn model(&self, path: &Path) -> Result<Arc<ModelKernels>> {
        Ok(self.cache.get_or_load(path)?.1)
    }

    /// Submit one request against the checkpoint at `path`. Returns a
    /// handle immediately; the response is computed as part of a
    /// coalesced micro-batch. Errors only when the checkpoint itself
    /// cannot be loaded — per-request failures arrive through the handle.
    pub fn submit(&self, path: &Path, input: Vec<f32>) -> Result<PendingResponse> {
        let (key, model) = self.cache.get_or_load(path)?;
        // Batchers whose model aged out of the cache are retired once
        // enough new keys accumulate, so the map tracks the cache instead
        // of growing with every checkpoint rewrite. Retired entries are
        // moved out under the lock but *dropped after releasing it*:
        // dropping a batcher joins its thread (which may be mid-flush or
        // waiting out `max_wait`), and that join must not stall every
        // other model's submissions on the map mutex.
        let mut retired: Vec<Arc<Batcher>> = Vec::new();
        let batcher = {
            let mut map = self.batchers.lock().unwrap();
            let batcher = map
                .entry(key)
                .or_insert_with(|| {
                    // The label keys per-model latency metrics: the path
                    // as clients submit it.
                    let local = LocalExecutor::new(
                        path.display().to_string(),
                        model,
                        self.pool.clone(),
                    );
                    let executor: Arc<dyn BatchExecutor> = match &self.router {
                        Some(router) if router.covers(path) => Arc::new(RoutedExecutor::new(
                            router.clone(),
                            local,
                            self.metrics.clone(),
                        )),
                        _ => Arc::new(local),
                    };
                    Arc::new(Batcher::spawn(
                        executor,
                        self.metrics.clone(),
                        BatcherConfig {
                            max_batch: self.config.max_batch,
                            max_wait: self.config.max_wait,
                            max_queue: self.config.max_queue,
                        },
                    ))
                })
                .clone();
            if map.len() > self.cache.capacity() * 2 {
                let cache = &self.cache;
                map.retain(|k, b| {
                    if cache.contains(k) {
                        true
                    } else {
                        retired.push(b.clone());
                        false
                    }
                });
            }
            batcher
        };
        drop(retired); // joins retired batcher threads outside the lock
        Ok(batcher.submit(input))
    }

    /// Convenience: submit one request and block for its output.
    pub fn infer(&self, path: &Path, input: Vec<f32>) -> Result<Vec<f32>> {
        self.submit(path, input)?.wait().map_err(|e| anyhow::anyhow!(e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::checkpoint::{store_weight, StoredWeight};
    use crate::io::tenz::TensorFile;
    use crate::rng::GaussianSource;
    use crate::tensor::init::gaussian;
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("serve_srv_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn write_model(path: &Path, seed: u64, c: usize, d: usize) {
        let mut g = GaussianSource::new(seed);
        let mut tf = TensorFile::new();
        store_weight(&mut tf, "head", &StoredWeight::Dense(gaussian(c, d, 1.0, &mut g)));
        tf.write(path).unwrap();
    }

    #[test]
    fn serves_two_models_from_one_process() {
        let dir = tmp_dir("two");
        let p1 = dir.join("a.tenz");
        let p2 = dir.join("b.tenz");
        write_model(&p1, 1, 2, 4);
        write_model(&p2, 2, 3, 5);
        let server = Server::new(ServeConfig {
            workers: 2,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        let y1 = server.infer(&p1, vec![1.0; 4]).unwrap();
        let y2 = server.infer(&p2, vec![1.0; 5]).unwrap();
        let y1b = server.infer(&p1, vec![2.0; 4]).unwrap();
        assert_eq!(y1.len(), 2);
        assert_eq!(y2.len(), 3);
        assert_eq!(y1b.len(), 2);
        // Linearity check: same model, doubled input ⇒ doubled output.
        for (a, b) in y1.iter().zip(y1b.iter()) {
            assert!((2.0 * a - b).abs() < 1e-4);
        }
        // Second request to model 1 hit the cache.
        let (hits, misses) = server.cache().stats();
        assert_eq!(misses, 2);
        assert_eq!(hits, 1);
        use std::sync::atomic::Ordering;
        assert_eq!(server.metrics().responses.load(Ordering::Relaxed), 3);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn load_error_surfaces_before_traffic() {
        let server = Server::new(ServeConfig::default());
        assert!(server.model(Path::new("/nonexistent/m.tenz")).is_err());
        assert!(server.submit(Path::new("/nonexistent/m.tenz"), vec![0.0]).is_err());
    }
}
