//! The serving engine: model cache + per-model batchers over one pool.
//!
//! One [`Server`] owns one persistent [`WorkerPool`] (the same pool type
//! the compression pipeline runs on), an LRU [`ModelCache`] keyed by
//! checkpoint path + per-file mtime snapshot (single `.tenz` containers
//! and sharded `.toml` manifests alike), and one [`Batcher`] per cached
//! model. Requests
//! against any number of checkpoints share the process: the first request
//! for a checkpoint loads and caches its kernels and spawns its batcher;
//! subsequent requests coalesce into batched GEMM passes.
//!
//! Multi-tenant serving goes through [`Server::submit_tenant`]: each
//! tenant carries a [`TenantPolicy`] (queue quota, deadline, DRR weight,
//! degrade sibling), and the admission controller here decides per
//! request between **admit** (queue as submitted), **degrade** (requeue
//! against the configured lower-rank/i8 sibling checkpoint — served, at
//! the accuracy cost the paper's ‖Δy‖ ≤ ‖W−UVᵀ‖₂‖x‖₂ bound prices), and
//! **shed** (answer with a shed error). Every decision lands in the
//! per-tenant [`ServeMetrics`] rows.

use super::batcher::{
    BatchExecutor, Batcher, BatcherConfig, LocalExecutor, PendingResponse, RequestError,
    TenantPolicy,
};
use super::cache::{ModelCache, ModelKey};
use super::cluster::{RoutedExecutor, Router};
use super::kernel::ModelKernels;
use super::metrics::ServeMetrics;
use crate::coordinator::pool::WorkerPool;
use anyhow::Result;
use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Server construction options (the `rsic serve` CLI flags).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Largest coalesced batch per GEMM pass.
    pub max_batch: usize,
    /// Longest a batch waits for more requests before flushing.
    pub max_wait: Duration,
    /// Worker threads executing batched forward passes.
    pub workers: usize,
    /// Bounded job-queue depth of the pool.
    pub queue_depth: usize,
    /// Per-model queued-request bound: submissions beyond it are shed
    /// ("server overloaded") instead of buffering without limit.
    pub max_queue: usize,
    /// Models kept resident in the LRU cache.
    pub cache_capacity: usize,
    /// Run the checkpoint integrity pass (`verify_hashes` on sharded
    /// checkpoints, a full structural read on single `.tenz`) at every
    /// model load, before any traffic is answered from it.
    pub verify: bool,
    /// Declared tenant policies (quota/deadline/weight/degrade sibling).
    /// Requests naming an undeclared tenant run under a per-name copy of
    /// the default policy.
    pub tenants: Vec<TenantPolicy>,
    /// Default per-tenant queue quota when a policy doesn't set one.
    pub tenant_quota: Option<usize>,
    /// Default queue deadline when a policy doesn't set one.
    pub deadline: Option<Duration>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            workers: crate::util::default_threads(),
            queue_depth: 16,
            max_queue: 8192,
            cache_capacity: 4,
            verify: false,
            tenants: Vec::new(),
            tenant_quota: None,
            deadline: None,
        }
    }
}

/// What the admission controller decided for one tenant submission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Queued against the checkpoint as submitted.
    Admitted,
    /// Requeued against the tenant's degrade sibling checkpoint.
    Degraded,
    /// Not served; the response handle resolves to a shed error.
    Shed,
}

/// One tenant submission: the admission decision plus the response
/// handle (already resolved for sheds).
pub struct TenantSubmission {
    pub outcome: Admission,
    pub response: PendingResponse,
}

/// A traffic-serving engine over compressed (or dense) checkpoints.
pub struct Server {
    // Declared before `pool`: batchers join their threads on drop while
    // the pool is still accepting the final flush jobs.
    batchers: Mutex<HashMap<ModelKey, Arc<Batcher>>>,
    pool: Arc<WorkerPool>,
    cache: Arc<ModelCache>,
    metrics: Arc<ServeMetrics>,
    config: ServeConfig,
    /// Declared tenant policies by name (shared with every submission).
    tenant_policies: HashMap<String, Arc<TenantPolicy>>,
    /// When set, batches for checkpoints the router's plan covers are
    /// shipped to cluster workers (with local failover); everything else
    /// executes in-process as before.
    router: Option<Arc<Router>>,
}

impl Server {
    pub fn new(config: ServeConfig) -> Server {
        Self::with_router(config, None)
    }

    /// A server whose micro-batcher drains into a cluster [`Router`] for
    /// the checkpoint the router's plan covers. Models are still loaded
    /// (and cached) locally — that is the failover target.
    pub fn with_router(config: ServeConfig, router: Option<Arc<Router>>) -> Server {
        let metrics = Arc::new(ServeMetrics::new());
        let mut tenant_policies = HashMap::new();
        for policy in &config.tenants {
            if let Some(slo) = policy.deadline.or(config.deadline) {
                metrics.set_tenant_slo(&policy.name, slo.as_secs_f64());
            }
            tenant_policies.insert(policy.name.to_string(), Arc::new(policy.clone()));
        }
        Server {
            batchers: Mutex::new(HashMap::new()),
            pool: Arc::new(WorkerPool::new(config.workers, config.queue_depth)),
            cache: Arc::new(ModelCache::with_verify(config.cache_capacity, config.verify)),
            metrics,
            config,
            tenant_policies,
            router,
        }
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    pub fn cache(&self) -> &ModelCache {
        &self.cache
    }

    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// The cluster router this server drains routed batches into, when
    /// one is attached (the metrics endpoint scrapes fleet-wide series
    /// through it).
    pub fn router(&self) -> Option<&Arc<Router>> {
        self.router.as_ref()
    }

    /// The declared policy for `tenant`, or a per-name copy of the
    /// default policy for tenants nobody declared.
    pub fn tenant_policy(&self, tenant: &str) -> Arc<TenantPolicy> {
        match self.tenant_policies.get(tenant) {
            Some(p) => p.clone(),
            None => Arc::new(TenantPolicy::named(tenant)),
        }
    }

    /// Load (or fetch from cache) the kernels for a checkpoint — also the
    /// warm-up/validation entry point: a bad checkpoint fails here, before
    /// any traffic is pointed at it.
    pub fn model(&self, path: &Path) -> Result<Arc<ModelKernels>> {
        Ok(self.cache.get_or_load(path)?.1)
    }

    /// The batcher serving `path`, spawning (and caching) it on first
    /// use. Errors only when the checkpoint itself cannot be loaded.
    fn batcher_for(&self, path: &Path) -> Result<Arc<Batcher>> {
        let (key, model) = self.cache.get_or_load(path)?;
        // Batchers whose model aged out of the cache are retired once
        // enough new keys accumulate, so the map tracks the cache instead
        // of growing with every checkpoint rewrite. Retired entries are
        // moved out under the lock but *dropped after releasing it*:
        // dropping a batcher joins its thread (which may be mid-flush or
        // waiting out `max_wait`), and that join must not stall every
        // other model's submissions on the map mutex.
        let mut retired: Vec<Arc<Batcher>> = Vec::new();
        let batcher = {
            let mut map = crate::util::lock_recover(&self.batchers);
            let batcher = map
                .entry(key)
                .or_insert_with(|| {
                    // The label keys per-model latency metrics: the path
                    // as clients submit it.
                    let local = LocalExecutor::new(
                        path.display().to_string(),
                        model,
                        self.pool.clone(),
                    );
                    let executor: Arc<dyn BatchExecutor> = match &self.router {
                        Some(router) if router.covers(path) => Arc::new(RoutedExecutor::new(
                            router.clone(),
                            local,
                            self.metrics.clone(),
                        )),
                        _ => Arc::new(local),
                    };
                    Arc::new(Batcher::spawn(
                        executor,
                        self.metrics.clone(),
                        BatcherConfig {
                            max_batch: self.config.max_batch,
                            max_wait: self.config.max_wait,
                            max_queue: self.config.max_queue,
                            tenant_quota: self.config.tenant_quota,
                            deadline: self.config.deadline,
                        },
                    ))
                })
                .clone();
            if map.len() > self.cache.capacity() * 2 {
                let cache = &self.cache;
                map.retain(|k, b| {
                    if cache.contains(k) {
                        true
                    } else {
                        retired.push(b.clone());
                        false
                    }
                });
            }
            batcher
        };
        drop(retired); // joins retired batcher threads outside the lock
        Ok(batcher)
    }

    /// Submit one request against the checkpoint at `path`. Returns a
    /// handle immediately; the response is computed as part of a
    /// coalesced micro-batch. Errors only when the checkpoint itself
    /// cannot be loaded — per-request failures arrive through the handle.
    pub fn submit(&self, path: &Path, input: Vec<f32>) -> Result<PendingResponse> {
        Ok(self.batcher_for(path)?.submit(input))
    }

    /// Submit one request on behalf of `tenant`, running the admission
    /// ladder: admit under the tenant's policy; on a quota/overload
    /// bounce, requeue against the policy's degrade sibling (quota-free —
    /// only the global bound applies to degraded traffic); shed when no
    /// rung is left. Errors only when a checkpoint cannot be loaded.
    pub fn submit_tenant(
        &self,
        path: &Path,
        tenant: &str,
        input: Vec<f32>,
    ) -> Result<TenantSubmission> {
        let policy = self.tenant_policy(tenant);
        self.metrics.tenant_offered(&policy.name);
        let batcher = self.batcher_for(path)?;
        let mut input = match batcher.try_submit(&policy, input) {
            Ok(response) => {
                self.metrics.tenant_admitted(&policy.name);
                record_admission(crate::obs::recorder::EventKind::Admitted, &policy.name, path);
                return Ok(TenantSubmission { outcome: Admission::Admitted, response });
            }
            Err(bounced) => bounced,
        };
        if let Some(sibling) = policy.degrade_to.as_ref() {
            if let Ok(sibling_batcher) = self.batcher_for(sibling) {
                let relaxed = TenantPolicy {
                    name: policy.name.clone(),
                    weight: policy.weight,
                    queue_quota: None,
                    deadline: policy.deadline,
                    degrade_to: None,
                };
                match sibling_batcher.try_submit(&relaxed, input) {
                    Ok(response) => {
                        self.metrics.tenant_degraded(&policy.name);
                        record_admission(
                            crate::obs::recorder::EventKind::Degraded,
                            &policy.name,
                            sibling,
                        );
                        return Ok(TenantSubmission { outcome: Admission::Degraded, response });
                    }
                    Err(bounced) => input = bounced,
                }
            }
        }
        drop(input);
        self.metrics.tenant_shed(&policy.name);
        record_admission(crate::obs::recorder::EventKind::Shed, &policy.name, path);
        Ok(TenantSubmission {
            outcome: Admission::Shed,
            response: PendingResponse::immediate_error(RequestError::Shed(format!(
                "tenant {tenant} over quota and no degrade capacity; request shed"
            ))),
        })
    }

    /// Convenience: submit one request and block for its output.
    pub fn infer(&self, path: &Path, input: Vec<f32>) -> Result<Vec<f32>> {
        self.submit(path, input)?.wait().map_err(|e| anyhow::anyhow!(e))
    }
}

/// Log one admission decision into the flight recorder (shed bursts
/// trip a postmortem dump there). The enable check here keeps the
/// disabled path free of the detail-string allocation.
fn record_admission(kind: crate::obs::recorder::EventKind, tenant: &str, path: &Path) {
    if crate::obs::enabled() {
        crate::obs::recorder::record(kind, format!("tenant={tenant} model={}", path.display()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::checkpoint::{store_weight, StoredWeight};
    use crate::io::tenz::TensorFile;
    use crate::rng::GaussianSource;
    use crate::tensor::init::gaussian;
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("serve_srv_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn write_model(path: &Path, seed: u64, c: usize, d: usize) {
        let mut g = GaussianSource::new(seed);
        let mut tf = TensorFile::new();
        store_weight(&mut tf, "head", &StoredWeight::Dense(gaussian(c, d, 1.0, &mut g)));
        tf.write(path).unwrap();
    }

    #[test]
    fn serves_two_models_from_one_process() {
        let dir = tmp_dir("two");
        let p1 = dir.join("a.tenz");
        let p2 = dir.join("b.tenz");
        write_model(&p1, 1, 2, 4);
        write_model(&p2, 2, 3, 5);
        let server = Server::new(ServeConfig {
            workers: 2,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        let y1 = server.infer(&p1, vec![1.0; 4]).unwrap();
        let y2 = server.infer(&p2, vec![1.0; 5]).unwrap();
        let y1b = server.infer(&p1, vec![2.0; 4]).unwrap();
        assert_eq!(y1.len(), 2);
        assert_eq!(y2.len(), 3);
        assert_eq!(y1b.len(), 2);
        // Linearity check: same model, doubled input ⇒ doubled output.
        for (a, b) in y1.iter().zip(y1b.iter()) {
            assert!((2.0 * a - b).abs() < 1e-4);
        }
        // Second request to model 1 hit the cache.
        let (hits, misses) = server.cache().stats();
        assert_eq!(misses, 2);
        assert_eq!(hits, 1);
        use std::sync::atomic::Ordering;
        assert_eq!(server.metrics().responses.load(Ordering::Relaxed), 3);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn load_error_surfaces_before_traffic() {
        let server = Server::new(ServeConfig::default());
        assert!(server.model(Path::new("/nonexistent/m.tenz")).is_err());
        assert!(server.submit(Path::new("/nonexistent/m.tenz"), vec![0.0]).is_err());
    }

    #[test]
    fn tenant_submission_admits_and_counts() {
        let dir = tmp_dir("tenant");
        let p = dir.join("m.tenz");
        write_model(&p, 3, 2, 4);
        let mut gold = TenantPolicy::named("gold");
        gold.weight = 2;
        let server = Server::new(ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            tenants: vec![gold],
            deadline: Some(Duration::from_secs(5)),
            ..Default::default()
        });
        let sub = server.submit_tenant(&p, "gold", vec![1.0; 4]).unwrap();
        assert_eq!(sub.outcome, Admission::Admitted);
        assert_eq!(sub.response.wait().unwrap().len(), 2);
        // Undeclared tenants run under a per-name default policy.
        let sub = server.submit_tenant(&p, "walk-in", vec![1.0; 4]).unwrap();
        assert_eq!(sub.outcome, Admission::Admitted);
        assert!(sub.response.wait().is_ok());
        let snaps = server.metrics().tenant_snapshots();
        let gold = snaps.iter().find(|s| s.tenant == "gold").unwrap();
        assert_eq!(gold.counters.offered, 1);
        assert_eq!(gold.counters.admitted, 1);
        assert!(gold.slo_secs.is_some(), "declared tenants inherit the config deadline as SLO");
        assert!(snaps.iter().any(|s| s.tenant == "walk-in"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn serving_survives_poisoned_locks() {
        // A request thread that panics while holding the serve-path locks
        // (batcher map, cache, metrics) must not take the server down for
        // everyone else: later submissions recover the locks and serve.
        let dir = tmp_dir("poison");
        let p = dir.join("m.tenz");
        write_model(&p, 6, 2, 4);
        let server = std::sync::Arc::new(Server::new(ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        }));
        let y1 = server.infer(&p, vec![1.0; 4]).unwrap();
        let s2 = std::sync::Arc::clone(&server);
        let _ = std::thread::spawn(move || {
            let _g = s2.batchers.lock().unwrap();
            panic!("injected panic while holding the batcher-map lock");
        })
        .join();
        assert!(server.batchers.lock().is_err(), "batcher map should be poisoned");
        let y2 = server.infer(&p, vec![1.0; 4]).unwrap();
        assert_eq!(y1, y2, "the same cached model must keep serving after the panic");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// With a zero quota and a degrade sibling, every request reroutes to
    /// the sibling (Degraded); without a sibling it sheds.
    #[test]
    fn degrade_ladder_reroutes_before_shedding() {
        let dir = tmp_dir("ladder");
        let primary = dir.join("primary.tenz");
        let sibling = dir.join("sibling.tenz");
        write_model(&primary, 4, 2, 4);
        write_model(&sibling, 5, 2, 4);
        let mut capped = TenantPolicy::named("capped");
        capped.queue_quota = Some(0);
        capped.degrade_to = Some(sibling.clone());
        let mut doomed = TenantPolicy::named("doomed");
        doomed.queue_quota = Some(0);
        let server = Server::new(ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            tenants: vec![capped, doomed],
            ..Default::default()
        });
        let sub = server.submit_tenant(&primary, "capped", vec![1.0; 4]).unwrap();
        assert_eq!(sub.outcome, Admission::Degraded);
        assert_eq!(sub.response.wait().unwrap().len(), 2);
        let sub = server.submit_tenant(&primary, "doomed", vec![1.0; 4]).unwrap();
        assert_eq!(sub.outcome, Admission::Shed);
        match sub.response.wait_outcome().unwrap_err() {
            RequestError::Shed(msg) => assert!(msg.contains("shed"), "{msg}"),
            other => panic!("expected shed, got {other:?}"),
        }
        let snaps = server.metrics().tenant_snapshots();
        let capped = snaps.iter().find(|s| s.tenant == "capped").unwrap();
        assert_eq!(capped.counters.degraded, 1);
        assert_eq!(capped.counters.shed, 0);
        let doomed = snaps.iter().find(|s| s.tenant == "doomed").unwrap();
        assert_eq!(doomed.counters.shed, 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
