//! Synthetic traffic driver — the one load generator behind both
//! `rsic serve` and `benches/serve_throughput.rs`, so the CLI and the CI
//! throughput gate measure exactly the same traffic shape.

use super::server::Server;
use crate::rng::GaussianSource;
use crate::util::timer::Stopwatch;
use anyhow::Result;
use std::path::PathBuf;
use std::sync::Arc;

/// What one traffic run did.
#[derive(Debug, Clone, Copy)]
pub struct TrafficReport {
    /// Requests submitted.
    pub requests: usize,
    /// Client threads that drove them.
    pub clients: usize,
    /// Wall time from first submission to last response.
    pub seconds: f64,
    /// Requests answered with an error (overload shedding, model
    /// failures) — the submissions themselves all succeeded.
    pub failed: usize,
}

impl TrafficReport {
    pub fn req_per_sec(&self) -> f64 {
        self.requests as f64 / self.seconds.max(1e-9)
    }
}

/// Drive `requests` Gaussian-vector requests round-robin across `paths`
/// from `clients` concurrent client threads. Each client submits its
/// whole share before waiting on any response — pipelined traffic, so
/// the micro-batcher sees genuine concurrency. Models are warm-loaded
/// first (a bad checkpoint fails here, before the clock starts).
///
/// Determinism is **`--clients`-aware**: client `i` draws from its own
/// `GaussianSource` seeded `seed ^ (i + 1)` and targets checkpoint
/// `(i + request) % paths.len()`, so the exact multiset of request
/// vectors (and their model routing) is a pure function of
/// `(requests, clients, seed, paths)` — independent of thread
/// scheduling. Comparing two runs (dense vs factored, local vs routed)
/// is only meaningful at the *same* client count: changing `clients`
/// re-partitions the per-client streams and produces different vectors,
/// which is why the routed-vs-local bench column holds `clients` fixed.
pub fn drive(
    server: &Arc<Server>,
    paths: &[PathBuf],
    requests: usize,
    clients: usize,
    seed: u64,
) -> Result<TrafficReport> {
    anyhow::ensure!(!paths.is_empty(), "no checkpoints to drive traffic at");
    let clients = clients.max(1);
    let mut dims = Vec::with_capacity(paths.len());
    for p in paths {
        dims.push(server.model(p)?.input_dim());
    }
    let sw = Stopwatch::start();
    let mut handles = Vec::with_capacity(clients);
    for client in 0..clients {
        let server = server.clone();
        let paths = paths.to_vec();
        let dims = dims.clone();
        let n = requests / clients + usize::from(client < requests % clients);
        handles.push(std::thread::spawn(move || -> Result<usize, String> {
            let mut g = GaussianSource::new(seed ^ (client as u64 + 1));
            let mut pending = Vec::with_capacity(n);
            for i in 0..n {
                let which = (client + i) % paths.len();
                let mut x = vec![0f32; dims[which]];
                g.fill_f32(&mut x);
                pending.push(server.submit(&paths[which], x).map_err(|e| e.to_string())?);
            }
            Ok(pending.into_iter().map(|p| usize::from(p.wait().is_err())).sum())
        }));
    }
    let mut failed = 0usize;
    for h in handles {
        failed += h
            .join()
            .map_err(|_| anyhow::anyhow!("traffic client thread panicked"))?
            .map_err(anyhow::Error::msg)?;
    }
    Ok(TrafficReport { requests, clients, seconds: sw.secs(), failed })
}
