//! Synthetic traffic driver — the one load generator behind both
//! `rsic serve` and `benches/serve_throughput.rs`, so the CLI and the CI
//! throughput gate measure exactly the same traffic shape. (Open-loop
//! scenario traffic lives in [`scenario`](super::scenario); this driver
//! is closed-loop and uniform, the baseline shape.)

use super::server::Server;
use crate::rng::GaussianSource;
use crate::util::timer::Stopwatch;
use anyhow::Result;
use std::path::PathBuf;
use std::sync::Arc;

/// What one traffic run did.
#[derive(Debug, Clone, Copy)]
pub struct TrafficReport {
    /// Requests submitted.
    pub requests: usize,
    /// Client threads that drove them.
    pub clients: usize,
    /// Wall time from first submission to last response.
    pub seconds: f64,
    /// Requests the server *chose* not to serve (overload shedding) —
    /// admission policy, not breakage.
    pub shed: usize,
    /// Requests answered with a non-shed error (model failure, wire
    /// error, shutdown) — the submissions themselves all succeeded.
    pub errored: usize,
    /// Model-cache misses observed *after* the warm-load pass: a cache
    /// smaller than the checkpoint set evicts mid-run, and every reload
    /// bills cold-start cost to request latency. Nonzero means the
    /// throughput numbers include reload stalls.
    pub mid_run_reloads: u64,
}

impl TrafficReport {
    /// Shed + errored — everything that didn't come back with an output.
    pub fn failed(&self) -> usize {
        self.shed + self.errored
    }

    /// Offered rate: every submission counts, served or not.
    pub fn req_per_sec(&self) -> f64 {
        self.requests as f64 / self.seconds.max(1e-9)
    }

    /// Useful throughput: only requests that came back with an output.
    /// The bench gates regress on this, so a build that "goes faster" by
    /// shedding load can't pass.
    pub fn goodput_per_sec(&self) -> f64 {
        (self.requests - self.failed()) as f64 / self.seconds.max(1e-9)
    }

    /// A human-readable warning when the warm-load guarantee was silently
    /// violated mid-run (see `mid_run_reloads`), `None` when it held.
    pub fn warm_cache_warning(&self) -> Option<String> {
        if self.mid_run_reloads == 0 {
            return None;
        }
        Some(format!(
            "warning: {} mid-run model reload(s) — the model cache is smaller than the \
             checkpoint set, so latency/throughput include cold reload stalls",
            self.mid_run_reloads
        ))
    }
}

/// Drive `requests` Gaussian-vector requests round-robin across `paths`
/// from `clients` concurrent client threads. Each client submits its
/// whole share before waiting on any response — pipelined traffic, so
/// the micro-batcher sees genuine concurrency. Models are warm-loaded
/// first (a bad checkpoint fails here, before the clock starts).
///
/// Determinism is **`--clients`-aware**: client `i` draws from its own
/// `GaussianSource` seeded `seed ^ (i + 1)` and targets checkpoint
/// `(i + request) % paths.len()`, so the exact multiset of request
/// vectors (and their model routing) is a pure function of
/// `(requests, clients, seed, paths)` — independent of thread
/// scheduling. Comparing two runs (dense vs factored, local vs routed)
/// is only meaningful at the *same* client count: changing `clients`
/// re-partitions the per-client streams and produces different vectors,
/// which is why the routed-vs-local bench column holds `clients` fixed.
pub fn drive(
    server: &Arc<Server>,
    paths: &[PathBuf],
    requests: usize,
    clients: usize,
    seed: u64,
) -> Result<TrafficReport> {
    anyhow::ensure!(!paths.is_empty(), "no checkpoints to drive traffic at");
    let clients = clients.max(1);
    let mut dims = Vec::with_capacity(paths.len());
    for p in paths {
        dims.push(server.model(p)?.input_dim());
    }
    // The warm loads above are the last misses the run should see; any
    // further miss is a mid-run eviction+reload billed to some request.
    let (_, warm_misses) = server.cache().stats();
    let sw = Stopwatch::start();
    let mut handles = Vec::with_capacity(clients);
    for client in 0..clients {
        let server = server.clone();
        let paths = paths.to_vec();
        let dims = dims.clone();
        let n = requests / clients + usize::from(client < requests % clients);
        handles.push(std::thread::spawn(move || -> Result<(usize, usize), String> {
            let mut g = GaussianSource::new(seed ^ (client as u64 + 1));
            let mut pending = Vec::with_capacity(n);
            for i in 0..n {
                let which = (client + i) % paths.len();
                let mut x = vec![0f32; dims[which]];
                g.fill_f32(&mut x);
                pending.push(server.submit(&paths[which], x).map_err(|e| e.to_string())?);
            }
            let (mut shed, mut errored) = (0usize, 0usize);
            for p in pending {
                match p.wait_outcome() {
                    Ok(_) => {}
                    Err(e) if e.is_shed() => shed += 1,
                    Err(_) => errored += 1,
                }
            }
            Ok((shed, errored))
        }));
    }
    let (mut shed, mut errored) = (0usize, 0usize);
    for h in handles {
        let (s, e) = h
            .join()
            .map_err(|_| anyhow::anyhow!("traffic client thread panicked"))?
            .map_err(anyhow::Error::msg)?;
        shed += s;
        errored += e;
    }
    let seconds = sw.secs();
    let (_, misses_after) = server.cache().stats();
    Ok(TrafficReport {
        requests,
        clients,
        seconds,
        shed,
        errored,
        mid_run_reloads: misses_after.saturating_sub(warm_misses),
    })
}
