//! Machine-readable compress reports: `COMPRESS_REPORT_<date>.json`.
//!
//! `rsic compress --report-out` persists one [`CompressReport`] per run —
//! the compression-path twin of `BENCH_<date>.json`. Each
//! [`LayerReport`] row carries the planner-facing cost signals for one
//! factorized layer: shape and rank, stage timings (read / factorize /
//! validate / quantize / write), the spectral error and σ_k/σ_{k+1} gap,
//! the per-power-iteration RSI convergence trace, and the stored-bytes
//! delta. The run header folds in the whole-run totals plus the
//! storage-tier I/O counters ([`crate::obs::iostat`]) observed during
//! the run.
//!
//! Hand-rolled JSON like `bench::record` (serde is not in the offline
//! crate universe); `from_json` is the strict parse-back twin that the
//! round-trip tests pin and that future planner tooling reads.

use super::record::{esc, num, parse_json, Json};
use crate::obs::compress::LayerTelemetry;
use crate::obs::iostat::IoSnapshot;
use std::path::{Path, PathBuf};

/// Per-layer entry of a compress report — the planner's future
/// cost-signal input.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LayerReport {
    pub layer: String,
    /// Logical shape (C, D).
    pub c: usize,
    pub d: usize,
    /// Factorization rank.
    pub k: usize,
    /// The resolved factorizer's self-description.
    pub method: String,
    pub read_secs: f64,
    pub factorize_secs: f64,
    pub validate_secs: f64,
    pub quantize_secs: f64,
    pub write_secs: f64,
    /// ‖W − A·B‖₂ estimate (`null` when validation was off).
    pub spectral_error: Option<f64>,
    /// σ_k and σ_{k+1} from the factorization's spectrum estimate —
    /// the gap is the rank-choice signal.
    pub sigma_k: f64,
    pub sigma_k1: f64,
    /// ‖WᵀXₜ‖_F after each power iteration — the RSI convergence trace.
    pub convergence: Vec<f64>,
    /// Stored bytes this layer occupied in the source checkpoint.
    pub bytes_before: u64,
    /// Stored bytes its factors occupy in the output.
    pub bytes_after: u64,
}

impl From<LayerTelemetry> for LayerReport {
    fn from(t: LayerTelemetry) -> Self {
        LayerReport {
            layer: t.layer,
            c: t.c,
            d: t.d,
            k: t.k,
            method: t.method,
            read_secs: t.read_secs,
            factorize_secs: t.factorize_secs,
            validate_secs: t.validate_secs,
            quantize_secs: t.quantize_secs,
            write_secs: t.write_secs,
            spectral_error: t.spectral_error,
            sigma_k: t.sigma_k,
            sigma_k1: t.sigma_k1,
            convergence: t.convergence,
            bytes_before: t.bytes_before,
            bytes_after: t.bytes_after,
        }
    }
}

/// One compress run, as written to `COMPRESS_REPORT_<date>.json`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CompressReport {
    pub date: String,
    pub git_rev: String,
    /// Plan method name (e.g. `rsi`).
    pub method: String,
    /// Resolved factorizer self-description.
    pub factorizer: String,
    pub backend: String,
    pub out_path: String,
    pub total_seconds: f64,
    /// Compressed/original parameter ratio over the whole model.
    pub ratio: f64,
    pub tensors_written: u64,
    pub shards: u64,
    pub layers_failed: u64,
    /// Storage-tier counter deltas observed over the run.
    pub io: IoSnapshot,
    pub layers: Vec<LayerReport>,
}

impl CompressReport {
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"date\": \"{}\",\n", esc(&self.date)));
        out.push_str(&format!("  \"git_rev\": \"{}\",\n", esc(&self.git_rev)));
        out.push_str(&format!("  \"method\": \"{}\",\n", esc(&self.method)));
        out.push_str(&format!("  \"factorizer\": \"{}\",\n", esc(&self.factorizer)));
        out.push_str(&format!("  \"backend\": \"{}\",\n", esc(&self.backend)));
        out.push_str(&format!("  \"out_path\": \"{}\",\n", esc(&self.out_path)));
        out.push_str(&format!("  \"total_seconds\": {},\n", num(self.total_seconds)));
        out.push_str(&format!("  \"ratio\": {},\n", num(self.ratio)));
        out.push_str(&format!("  \"tensors_written\": {},\n", self.tensors_written));
        out.push_str(&format!("  \"shards\": {},\n", self.shards));
        out.push_str(&format!("  \"layers_failed\": {},\n", self.layers_failed));
        out.push_str("  \"io\": {\n");
        let io = &self.io;
        out.push_str(&format!("    \"mmap_read_bytes\": {},\n", io.mmap_read_bytes));
        out.push_str(&format!("    \"pread_read_bytes\": {},\n", io.pread_read_bytes));
        out.push_str(&format!("    \"seek_read_bytes\": {},\n", io.seek_read_bytes));
        out.push_str(&format!("    \"chunk_cache_hits\": {},\n", io.chunk_cache_hits));
        out.push_str(&format!("    \"chunk_cache_misses\": {},\n", io.chunk_cache_misses));
        out.push_str(&format!(
            "    \"chunk_decompressed_bytes\": {},\n",
            io.chunk_decompressed_bytes
        ));
        out.push_str(&format!("    \"writer_bytes\": {},\n", io.writer_bytes));
        out.push_str(&format!("    \"madvise_willneed\": {},\n", io.madvise_willneed));
        out.push_str(&format!("    \"madvise_dontneed\": {},\n", io.madvise_dontneed));
        out.push_str(&format!("    \"exec_cache_hits\": {},\n", io.exec_cache_hits));
        out.push_str(&format!("    \"exec_cache_misses\": {}\n", io.exec_cache_misses));
        out.push_str("  },\n");
        out.push_str("  \"layers\": [\n");
        for (i, l) in self.layers.iter().enumerate() {
            out.push_str("    {\n");
            out.push_str(&format!("      \"layer\": \"{}\",\n", esc(&l.layer)));
            out.push_str(&format!("      \"c\": {},\n", l.c));
            out.push_str(&format!("      \"d\": {},\n", l.d));
            out.push_str(&format!("      \"k\": {},\n", l.k));
            out.push_str(&format!("      \"method\": \"{}\",\n", esc(&l.method)));
            out.push_str(&format!("      \"read_secs\": {},\n", num(l.read_secs)));
            out.push_str(&format!("      \"factorize_secs\": {},\n", num(l.factorize_secs)));
            out.push_str(&format!("      \"validate_secs\": {},\n", num(l.validate_secs)));
            out.push_str(&format!("      \"quantize_secs\": {},\n", num(l.quantize_secs)));
            out.push_str(&format!("      \"write_secs\": {},\n", num(l.write_secs)));
            match l.spectral_error {
                Some(e) => out.push_str(&format!("      \"spectral_error\": {},\n", num(e))),
                None => out.push_str("      \"spectral_error\": null,\n"),
            }
            out.push_str(&format!("      \"sigma_k\": {},\n", num(l.sigma_k)));
            out.push_str(&format!("      \"sigma_k1\": {},\n", num(l.sigma_k1)));
            let trace: Vec<String> = l.convergence.iter().map(|&v| num(v)).collect();
            out.push_str(&format!("      \"convergence\": [{}],\n", trace.join(", ")));
            out.push_str(&format!("      \"bytes_before\": {},\n", l.bytes_before));
            out.push_str(&format!("      \"bytes_after\": {}\n", l.bytes_after));
            out.push_str(&format!("    }}{}\n", if i + 1 < self.layers.len() { "," } else { "" }));
        }
        out.push_str("  ]\n}\n");
        out
    }

    pub fn from_json(text: &str) -> Result<CompressReport, String> {
        let v = parse_json(text)?;
        let s = |key: &str| -> Result<String, String> {
            v.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("missing \"{key}\""))
        };
        let f = |key: &str| -> Result<f64, String> {
            v.get(key).and_then(Json::as_f64).ok_or_else(|| format!("missing \"{key}\""))
        };
        let io_obj = v.get("io").ok_or("missing \"io\"")?;
        let io_u = |key: &str| -> Result<u64, String> {
            io_obj
                .get(key)
                .and_then(Json::as_f64)
                .map(|x| x as u64)
                .ok_or_else(|| format!("missing \"io.{key}\""))
        };
        let io = IoSnapshot {
            mmap_read_bytes: io_u("mmap_read_bytes")?,
            pread_read_bytes: io_u("pread_read_bytes")?,
            seek_read_bytes: io_u("seek_read_bytes")?,
            chunk_cache_hits: io_u("chunk_cache_hits")?,
            chunk_cache_misses: io_u("chunk_cache_misses")?,
            chunk_decompressed_bytes: io_u("chunk_decompressed_bytes")?,
            writer_bytes: io_u("writer_bytes")?,
            madvise_willneed: io_u("madvise_willneed")?,
            madvise_dontneed: io_u("madvise_dontneed")?,
            exec_cache_hits: io_u("exec_cache_hits")?,
            exec_cache_misses: io_u("exec_cache_misses")?,
        };
        let mut layers = Vec::new();
        for l in v.get("layers").and_then(Json::as_arr).ok_or("missing \"layers\"")? {
            let ls = |key: &str| -> Result<String, String> {
                l.get(key)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| format!("missing layer \"{key}\""))
            };
            let lf = |key: &str| -> Result<f64, String> {
                l.get(key).and_then(Json::as_f64).ok_or_else(|| format!("missing layer \"{key}\""))
            };
            let spectral_error = match l.get("spectral_error") {
                Some(Json::Null) => None,
                Some(j) => Some(j.as_f64().ok_or("bad \"spectral_error\"")?),
                None => return Err("missing layer \"spectral_error\"".into()),
            };
            let convergence = l
                .get("convergence")
                .and_then(Json::as_arr)
                .ok_or("missing layer \"convergence\"")?
                .iter()
                .map(|j| j.as_f64().ok_or_else(|| "bad convergence entry".to_string()))
                .collect::<Result<Vec<f64>, String>>()?;
            layers.push(LayerReport {
                layer: ls("layer")?,
                c: lf("c")? as usize,
                d: lf("d")? as usize,
                k: lf("k")? as usize,
                method: ls("method")?,
                read_secs: lf("read_secs")?,
                factorize_secs: lf("factorize_secs")?,
                validate_secs: lf("validate_secs")?,
                quantize_secs: lf("quantize_secs")?,
                write_secs: lf("write_secs")?,
                spectral_error,
                sigma_k: lf("sigma_k")?,
                sigma_k1: lf("sigma_k1")?,
                convergence,
                bytes_before: lf("bytes_before")? as u64,
                bytes_after: lf("bytes_after")? as u64,
            });
        }
        Ok(CompressReport {
            date: s("date")?,
            git_rev: s("git_rev")?,
            method: s("method")?,
            factorizer: s("factorizer")?,
            backend: s("backend")?,
            out_path: s("out_path")?,
            total_seconds: f("total_seconds")?,
            ratio: f("ratio")?,
            tensors_written: f("tensors_written")? as u64,
            shards: f("shards")? as u64,
            layers_failed: f("layers_failed")? as u64,
            io,
            layers,
        })
    }

    /// Write as `COMPRESS_REPORT_<date>.json` under `dir`; returns the
    /// written path. Same naming discipline as `BenchRecord::write_to`.
    pub fn write_to(&self, dir: &Path) -> std::io::Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("COMPRESS_REPORT_{}.json", self.date));
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CompressReport {
        CompressReport {
            date: "2026-08-08".into(),
            git_rev: "abc1234".into(),
            method: "rsi".into(),
            factorizer: "rsi(q=2)".into(),
            backend: "native".into(),
            out_path: "/tmp/out.tenz".into(),
            total_seconds: 1.25,
            ratio: 0.31,
            tensors_written: 7,
            shards: 2,
            layers_failed: 0,
            io: IoSnapshot {
                mmap_read_bytes: 4096,
                pread_read_bytes: 0,
                seek_read_bytes: 12,
                chunk_cache_hits: 3,
                chunk_cache_misses: 1,
                chunk_decompressed_bytes: 65536,
                writer_bytes: 2048,
                madvise_willneed: 2,
                madvise_dontneed: 2,
                exec_cache_hits: 0,
                exec_cache_misses: 0,
            },
            layers: vec![
                LayerReport {
                    layer: "layers.0".into(),
                    c: 24,
                    d: 60,
                    k: 7,
                    method: "rsi(q=2)".into(),
                    read_secs: 0.001,
                    factorize_secs: 0.05,
                    validate_secs: 0.002,
                    quantize_secs: 0.0005,
                    write_secs: 0.0009,
                    spectral_error: Some(0.125),
                    sigma_k: 1.5,
                    sigma_k1: 0.4,
                    convergence: vec![10.0, 10.6, 10.61],
                    bytes_before: 5760,
                    bytes_after: 2352,
                },
                LayerReport {
                    layer: "head \"odd\"".into(),
                    spectral_error: None,
                    ..Default::default()
                },
            ],
        }
    }

    #[test]
    fn json_roundtrip_preserves_every_field() {
        let rec = sample();
        let back = CompressReport::from_json(&rec.to_json()).unwrap();
        assert_eq!(back, rec);
    }

    #[test]
    fn parser_rejects_malformed_and_truncated_reports() {
        assert!(CompressReport::from_json("{").is_err());
        assert!(CompressReport::from_json("[]").is_err());
        assert!(CompressReport::from_json("{\"date\": \"x\"}").is_err());
        let mut text = sample().to_json();
        text.push('x');
        assert!(CompressReport::from_json(&text).is_err(), "trailing bytes must be rejected");
    }

    #[test]
    fn write_to_names_the_file_by_date() {
        let dir =
            std::env::temp_dir().join(format!("compress_report_{}", std::process::id()));
        let rec = sample();
        let path = rec.write_to(&dir).unwrap();
        assert!(path.ends_with("COMPRESS_REPORT_2026-08-08.json"));
        let back = CompressReport::from_json(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back, rec);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
