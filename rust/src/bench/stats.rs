//! Summary statistics over benchmark samples.

/// Summary of a sample set (seconds or any unit).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    /// Compute from raw samples. Empty input yields a zeroed summary.
    pub fn from_samples(samples: &[f64]) -> Summary {
        let n = samples.len();
        if n == 0 {
            return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, p50: 0.0, p95: 0.0, max: 0.0 };
        }
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            max: sorted[n - 1],
        }
    }

    /// Relative standard deviation (coefficient of variation).
    pub fn rel_std(&self) -> f64 {
        if self.mean.abs() < f64::MIN_POSITIVE {
            0.0
        } else {
            self.std / self.mean
        }
    }
}

/// Linear-interpolated percentile of a sorted slice, p in [0,1].
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let p = p.clamp(0.0, 1.0);
    let idx = p * (sorted.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = idx - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Geometric mean (used for speedup aggregation across ranks).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|v| v.max(f64::MIN_POSITIVE).ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_summary() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn empty_and_single() {
        let e = Summary::from_samples(&[]);
        assert_eq!(e.n, 0);
        let s = Summary::from_samples(&[7.0]);
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p95, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile(&sorted, 0.5), 5.0);
        assert_eq!(percentile(&sorted, 0.0), 0.0);
        assert_eq!(percentile(&sorted, 1.0), 10.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn rel_std() {
        let s = Summary::from_samples(&[9.0, 11.0]);
        assert!((s.rel_std() - (2.0f64).sqrt() / 10.0).abs() < 1e-12);
    }
}
