//! Warmup + timed-iteration benchmark runner with table output.
//!
//! Intentionally criterion-shaped: `harness.bench("name", || work())`
//! runs warmup iterations, then timed samples, and records a [`Summary`].
//! Unlike criterion we also support *single-shot* measurements
//! (`bench_once`) for expensive end-to-end cells (Table 4.1 rows), where
//! the paper itself reports one run.

use super::stats::Summary;
use crate::util::fmt_duration;
use crate::util::timer::Stopwatch;

/// One benchmark's recorded outcome.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

/// Harness configuration + result sink.
#[derive(Debug)]
pub struct Harness {
    warmup_iters: usize,
    sample_iters: usize,
    max_seconds: f64,
    results: Vec<BenchResult>,
    quiet: bool,
}

impl Default for Harness {
    fn default() -> Self {
        Harness { warmup_iters: 1, sample_iters: 10, max_seconds: 30.0, results: vec![], quiet: false }
    }
}

impl Harness {
    pub fn new(warmup_iters: usize, sample_iters: usize) -> Self {
        Harness { warmup_iters, sample_iters, ..Default::default() }
    }

    /// Cap total sampling time per benchmark; sampling stops early once
    /// exceeded (at least one sample is always taken).
    pub fn with_max_seconds(mut self, secs: f64) -> Self {
        self.max_seconds = secs;
        self
    }

    pub fn quiet(mut self) -> Self {
        self.quiet = true;
        self
    }

    /// Honor `RSIC_BENCH_FAST=1`: slash iteration counts (CI smoke mode).
    pub fn from_env() -> Self {
        let fast = std::env::var("RSIC_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
        if fast {
            Harness::new(0, 3).with_max_seconds(5.0)
        } else {
            Harness::default()
        }
    }

    /// Benchmark a closure; returns the summary and records it.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> Summary {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.sample_iters);
        let budget = Stopwatch::start();
        for _ in 0..self.sample_iters.max(1) {
            let sw = Stopwatch::start();
            std::hint::black_box(f());
            samples.push(sw.secs());
            if budget.secs() > self.max_seconds {
                break;
            }
        }
        let summary = Summary::from_samples(&samples);
        if !self.quiet {
            println!(
                "bench {name:<42} {:>12} ± {:>10}  (n={}, p95 {})",
                fmt_duration(summary.mean),
                fmt_duration(summary.std),
                summary.n,
                fmt_duration(summary.p95),
            );
        }
        self.results.push(BenchResult { name: name.to_string(), summary: summary.clone() });
        summary
    }

    /// Record an externally-measured sample set under a name.
    pub fn record(&mut self, name: &str, samples: &[f64]) -> Summary {
        let summary = Summary::from_samples(samples);
        self.results.push(BenchResult { name: name.to_string(), summary: summary.clone() });
        summary
    }

    /// One timed execution (no warmup) — for expensive end-to-end cells.
    pub fn bench_once<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> (T, f64) {
        let sw = Stopwatch::start();
        let out = f();
        let secs = sw.secs();
        self.results.push(BenchResult {
            name: name.to_string(),
            summary: Summary::from_samples(&[secs]),
        });
        if !self.quiet {
            println!("bench {name:<42} {:>12}  (single shot)", fmt_duration(secs));
        }
        (out, secs)
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Render all recorded results as an aligned text table.
    pub fn table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<44} {:>12} {:>12} {:>12} {:>6}\n",
            "benchmark", "mean", "std", "p95", "n"
        ));
        for r in &self.results {
            out.push_str(&format!(
                "{:<44} {:>12} {:>12} {:>12} {:>6}\n",
                r.name,
                fmt_duration(r.summary.mean),
                fmt_duration(r.summary.std),
                fmt_duration(r.summary.p95),
                r.summary.n
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut h = Harness::new(1, 5).quiet();
        let mut count = 0usize;
        let s = h.bench("noop", || count += 1);
        assert_eq!(s.n, 5);
        assert_eq!(count, 6); // warmup + samples
        assert_eq!(h.results().len(), 1);
        assert!(h.table().contains("noop"));
    }

    #[test]
    fn budget_stops_early() {
        let mut h = Harness::new(0, 1000).with_max_seconds(0.02).quiet();
        let s = h.bench("sleepy", || std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(s.n < 1000, "early stop expected, ran {}", s.n);
        assert!(s.n >= 1);
    }

    #[test]
    fn bench_once_returns_value() {
        let mut h = Harness::default().quiet();
        let (v, secs) = h.bench_once("compute", || 21 * 2);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn record_external_samples() {
        let mut h = Harness::default().quiet();
        let s = h.record("ext", &[1.0, 3.0]);
        assert_eq!(s.mean, 2.0);
    }
}
