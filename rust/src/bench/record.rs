//! Recorded perf trajectory: each `serve_throughput` run writes a
//! `BENCH_<iso-date>.json` snapshot (shapes, kernels, req/s, GFLOP/s,
//! speedup vs dense, git rev) into the repo root, and can compare itself
//! against the latest previous snapshot — with `RSIC_BENCH_ENFORCE=1` a
//! >10% req/s regression fails the run. serde is not in the offline crate
//! universe, so the JSON emitter and the (minimal, strict) parser live
//! here.

use std::path::{Path, PathBuf};

/// One measured bench configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRow {
    /// Layer shape, e.g. `"1024x4096"`.
    pub shape: String,
    /// Kernel under test: `dense`, `factored-f32`, `factored-i8`, …
    pub kernel: String,
    /// Compression ratio α (0 for dense).
    pub alpha: f64,
    pub req_per_s: f64,
    /// Useful arithmetic rate: 2·MACs·req/s / 1e9.
    pub gflops: f64,
    /// req/s relative to the dense kernel on the same shape.
    pub speedup_vs_dense: f64,
}

/// One run's snapshot — what a `BENCH_<date>.json` file holds.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRecord {
    /// UTC date the run finished (also the filename key).
    pub date: String,
    /// `git rev-parse --short HEAD`, or `"unknown"` outside a work tree.
    pub git_rev: String,
    /// Whether the run used the `RSIC_BENCH_FAST=1` smoke settings —
    /// fast and full runs are only ever compared like-for-like.
    pub fast: bool,
    pub rows: Vec<BenchRow>,
}

impl BenchRecord {
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"date\": \"{}\",\n", esc(&self.date)));
        out.push_str(&format!("  \"git_rev\": \"{}\",\n", esc(&self.git_rev)));
        out.push_str(&format!("  \"fast\": {},\n", self.fast));
        out.push_str("  \"rows\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"shape\": \"{}\", \"kernel\": \"{}\", \"alpha\": {}, \
                 \"req_per_s\": {}, \"gflops\": {}, \"speedup_vs_dense\": {}}}{}\n",
                esc(&r.shape),
                esc(&r.kernel),
                num(r.alpha),
                num(r.req_per_s),
                num(r.gflops),
                num(r.speedup_vs_dense),
                if i + 1 < self.rows.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    pub fn from_json(text: &str) -> Result<BenchRecord, String> {
        let mut p = Parser { s: text.as_bytes(), i: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            return Err(format!("trailing bytes at offset {}", p.i));
        }
        let date = v.get("date").and_then(Json::as_str).ok_or("missing \"date\"")?.to_string();
        let git_rev =
            v.get("git_rev").and_then(Json::as_str).ok_or("missing \"git_rev\"")?.to_string();
        let fast = v.get("fast").and_then(Json::as_bool).ok_or("missing \"fast\"")?;
        let mut rows = Vec::new();
        for r in v.get("rows").and_then(Json::as_arr).ok_or("missing \"rows\"")? {
            let field = |k: &str| {
                r.get(k).and_then(Json::as_f64).ok_or_else(|| format!("row missing {k:?}"))
            };
            rows.push(BenchRow {
                shape: r.get("shape").and_then(Json::as_str).ok_or("row missing \"shape\"")?.into(),
                kernel: r
                    .get("kernel")
                    .and_then(Json::as_str)
                    .ok_or("row missing \"kernel\"")?
                    .into(),
                alpha: field("alpha")?,
                req_per_s: field("req_per_s")?,
                gflops: field("gflops")?,
                speedup_vs_dense: field("speedup_vs_dense")?,
            });
        }
        Ok(BenchRecord { date, git_rev, fast, rows })
    }

    /// Write `BENCH_<date>.json` into `dir` (same-day reruns overwrite).
    pub fn write_to(&self, dir: &Path) -> std::io::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.date));
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }

    /// Latest readable `BENCH_*.json` in `dir` whose `fast` flag matches —
    /// the comparison baseline. ISO dates in the filename sort
    /// chronologically, so lexicographic order is time order.
    pub fn latest_in(dir: &Path, fast: bool) -> Option<(PathBuf, BenchRecord)> {
        let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
            .ok()?
            .flatten()
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
                    .unwrap_or(false)
            })
            .collect();
        paths.sort();
        while let Some(path) = paths.pop() {
            let Ok(text) = std::fs::read_to_string(&path) else { continue };
            let Ok(rec) = BenchRecord::from_json(&text) else { continue };
            if rec.fast == fast {
                return Some((path, rec));
            }
        }
        None
    }

    /// Regression messages: rows whose req/s dropped more than 10% below
    /// the same (shape, kernel, α) row of `baseline`. Rows present on only
    /// one side are not regressions.
    pub fn regressions_vs(&self, baseline: &BenchRecord) -> Vec<String> {
        let mut out = Vec::new();
        for row in &self.rows {
            let base = baseline.rows.iter().find(|b| {
                b.shape == row.shape
                    && b.kernel == row.kernel
                    && (b.alpha - row.alpha).abs() < 1e-12
            });
            let Some(base) = base else { continue };
            if base.req_per_s > 0.0 && row.req_per_s < 0.90 * base.req_per_s {
                out.push(format!(
                    "{} {} α={}: {:.1} req/s vs baseline {:.1} ({:+.1}%)",
                    row.shape,
                    row.kernel,
                    row.alpha,
                    row.req_per_s,
                    base.req_per_s,
                    (row.req_per_s / base.req_per_s - 1.0) * 100.0
                ));
            }
        }
        out
    }
}

/// One load-factor point on a soak run's degradation curve.
#[derive(Debug, Clone, PartialEq)]
pub struct SoakPoint {
    /// Load multiplier applied to the scenario's base rates.
    pub factor: f64,
    /// Offered arrival rate (requests/sec the generator produced).
    pub offered_per_s: f64,
    /// Completed requests/sec — sheds and errors excluded.
    pub goodput_per_s: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Fraction of offered requests shed (admission + deadline).
    pub shed_rate: f64,
    /// Fraction of offered requests served by a degrade sibling.
    pub degraded_rate: f64,
}

/// A soak run's snapshot — what a `SOAK_<date>.json` file holds: the
/// degradation curve the CI traffic-soak step uploads as an artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct SoakRecord {
    /// UTC date the run finished (also the filename key).
    pub date: String,
    /// `git rev-parse --short HEAD`, or `"unknown"` outside a work tree.
    pub git_rev: String,
    /// Scenario name the curve was swept over.
    pub scenario: String,
    /// Whether the run used the CI fast settings (`RSIC_SOAK_FAST=1`).
    pub fast: bool,
    pub points: Vec<SoakPoint>,
}

impl SoakRecord {
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"date\": \"{}\",\n", esc(&self.date)));
        out.push_str(&format!("  \"git_rev\": \"{}\",\n", esc(&self.git_rev)));
        out.push_str(&format!("  \"scenario\": \"{}\",\n", esc(&self.scenario)));
        out.push_str(&format!("  \"fast\": {},\n", self.fast));
        out.push_str("  \"points\": [\n");
        for (i, p) in self.points.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"factor\": {}, \"offered_per_s\": {}, \"goodput_per_s\": {}, \
                 \"p50_ms\": {}, \"p99_ms\": {}, \"shed_rate\": {}, \"degraded_rate\": {}}}{}\n",
                num(p.factor),
                num(p.offered_per_s),
                num(p.goodput_per_s),
                num(p.p50_ms),
                num(p.p99_ms),
                num(p.shed_rate),
                num(p.degraded_rate),
                if i + 1 < self.points.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    pub fn from_json(text: &str) -> Result<SoakRecord, String> {
        let mut p = Parser { s: text.as_bytes(), i: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            return Err(format!("trailing bytes at offset {}", p.i));
        }
        let date = v.get("date").and_then(Json::as_str).ok_or("missing \"date\"")?.to_string();
        let git_rev =
            v.get("git_rev").and_then(Json::as_str).ok_or("missing \"git_rev\"")?.to_string();
        let scenario =
            v.get("scenario").and_then(Json::as_str).ok_or("missing \"scenario\"")?.to_string();
        let fast = v.get("fast").and_then(Json::as_bool).ok_or("missing \"fast\"")?;
        let mut points = Vec::new();
        for r in v.get("points").and_then(Json::as_arr).ok_or("missing \"points\"")? {
            let field = |k: &str| {
                r.get(k).and_then(Json::as_f64).ok_or_else(|| format!("point missing {k:?}"))
            };
            points.push(SoakPoint {
                factor: field("factor")?,
                offered_per_s: field("offered_per_s")?,
                goodput_per_s: field("goodput_per_s")?,
                p50_ms: field("p50_ms")?,
                p99_ms: field("p99_ms")?,
                shed_rate: field("shed_rate")?,
                degraded_rate: field("degraded_rate")?,
            });
        }
        Ok(SoakRecord { date, git_rev, scenario, fast, points })
    }

    /// Write `SOAK_<date>.json` into `dir` (same-day reruns overwrite).
    pub fn write_to(&self, dir: &Path) -> std::io::Result<PathBuf> {
        let path = dir.join(format!("SOAK_{}.json", self.date));
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }

    /// Latest readable `SOAK_*.json` in `dir` whose `fast` flag matches.
    pub fn latest_in(dir: &Path, fast: bool) -> Option<(PathBuf, SoakRecord)> {
        let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
            .ok()?
            .flatten()
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map(|n| n.starts_with("SOAK_") && n.ends_with(".json"))
                    .unwrap_or(false)
            })
            .collect();
        paths.sort();
        while let Some(path) = paths.pop() {
            let Ok(text) = std::fs::read_to_string(&path) else { continue };
            let Ok(rec) = SoakRecord::from_json(&text) else { continue };
            if rec.fast == fast {
                return Some((path, rec));
            }
        }
        None
    }
}

/// Directory BENCH files live in: `$RSIC_BENCH_DIR` when set, else the
/// repo root (benches run with `rust/` as the working directory), else
/// the working directory itself.
pub fn bench_dir() -> PathBuf {
    if let Ok(d) = std::env::var("RSIC_BENCH_DIR") {
        return PathBuf::from(d);
    }
    let parent = Path::new("..");
    if parent.join("ROADMAP.md").is_file() {
        return parent.to_path_buf();
    }
    PathBuf::from(".")
}

/// `RSIC_BENCH_ENFORCE=1`: regressions fail the bench run instead of
/// merely printing.
pub fn enforce() -> bool {
    std::env::var("RSIC_BENCH_ENFORCE").map(|v| v == "1").unwrap_or(false)
}

/// Short git revision of the working tree, `"unknown"` when unavailable.
pub fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

/// Today's UTC date, `YYYY-MM-DD`.
pub fn today_utc() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let (y, m, d) = civil_from_days((secs / 86_400) as i64);
    format!("{y:04}-{m:02}-{d:02}")
}

/// Days-since-epoch → (year, month, day), proleptic Gregorian
/// (Howard Hinnant's `civil_from_days` algorithm).
fn civil_from_days(z: i64) -> (i64, u32, u32) {
    let z = z + 719_468;
    let era = if z >= 0 { z } else { z - 146_096 } / 146_097;
    let doe = (z - era * 146_097) as u64;
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe as i64 + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32;
    let m = if mp < 10 { mp + 3 } else { mp - 9 };
    let y = if m <= 2 { y + 1 } else { y };
    (y, m as u32, d)
}

pub(crate) fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// JSON-safe float text (`Display` for f64 is shortest-round-trip).
pub(crate) fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".into()
    }
}

/// Minimal strict JSON value + recursive-descent parser — just enough to
/// read back the snapshots this module writes.
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub(crate) fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
    pub(crate) fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub(crate) fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }
    pub(crate) fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub(crate) fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse a complete JSON document, rejecting trailing bytes — the
/// shared entry point for every hand-rolled snapshot reader in the
/// crate (bench records, compress reports).
pub(crate) fn parse_json(text: &str) -> Result<Json, String> {
    let mut p = Parser { s: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.s.len() {
        return Err(format!("trailing bytes at offset {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at offset {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek().ok_or("unexpected end of input")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at offset {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            out.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at offset {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at offset {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out: Vec<u8> = Vec::new();
        loop {
            let c = *self.s.get(self.i).ok_or("unterminated string")?;
            self.i += 1;
            match c {
                b'"' => {
                    return String::from_utf8(out)
                        .map_err(|_| String::from("invalid utf-8 in string"))
                }
                b'\\' => {
                    let e = *self.s.get(self.i).ok_or("unterminated escape")?;
                    self.i += 1;
                    let ch = match e {
                        b'"' => '"',
                        b'\\' => '\\',
                        b'/' => '/',
                        b'n' => '\n',
                        b't' => '\t',
                        b'r' => '\r',
                        b'u' => {
                            let hex = self.s.get(self.i..self.i + 4).ok_or("bad \\u escape")?;
                            let txt =
                                std::str::from_utf8(hex).map_err(|_| String::from("bad \\u"))?;
                            let code = u32::from_str_radix(txt, 16)
                                .map_err(|_| String::from("bad \\u escape"))?;
                            self.i += 4;
                            char::from_u32(code).unwrap_or('\u{fffd}')
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    };
                    let mut buf = [0u8; 4];
                    out.extend_from_slice(ch.encode_utf8(&mut buf).as_bytes());
                }
                other => out.push(other),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let txt = std::str::from_utf8(&self.s[start..self.i])
            .map_err(|_| String::from("bad number"))?;
        txt.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {txt:?}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BenchRecord {
        BenchRecord {
            date: "2026-08-08".into(),
            git_rev: "abc123\"\\".into(),
            fast: true,
            rows: vec![
                BenchRow {
                    shape: "1024x4096".into(),
                    kernel: "dense".into(),
                    alpha: 0.0,
                    req_per_s: 100.5,
                    gflops: 12.25,
                    speedup_vs_dense: 1.0,
                },
                BenchRow {
                    shape: "1024x4096".into(),
                    kernel: "factored-f32".into(),
                    alpha: 0.1,
                    req_per_s: 321.0,
                    gflops: 7.5,
                    speedup_vs_dense: 3.194,
                },
            ],
        }
    }

    #[test]
    fn json_roundtrip() {
        let rec = sample();
        let back = BenchRecord::from_json(&rec.to_json()).unwrap();
        assert_eq!(back, rec);
    }

    #[test]
    fn parser_rejects_malformed_input() {
        assert!(BenchRecord::from_json("{").is_err());
        assert!(BenchRecord::from_json("[]").is_err());
        assert!(BenchRecord::from_json("{\"date\": \"x\"}").is_err());
        let mut text = sample().to_json();
        text.push('x');
        assert!(BenchRecord::from_json(&text).is_err(), "trailing bytes must be rejected");
    }

    #[test]
    fn regression_detection_is_keyed_and_thresholded() {
        let base = sample();
        let mut run = sample();
        // 5% slower: within tolerance.
        run.rows[1].req_per_s = 0.95 * base.rows[1].req_per_s;
        assert!(run.regressions_vs(&base).is_empty());
        // 15% slower: flagged, keyed to the factored row only.
        run.rows[1].req_per_s = 0.85 * base.rows[1].req_per_s;
        let regs = run.regressions_vs(&base);
        assert_eq!(regs.len(), 1);
        assert!(regs[0].contains("factored-f32"), "{}", regs[0]);
        // A row with no baseline counterpart is not a regression.
        run.rows[1].kernel = "factored-i8".into();
        assert!(run.regressions_vs(&base).is_empty());
    }

    #[test]
    fn soak_record_roundtrips_and_latest_matches_the_fast_flag() {
        let rec = SoakRecord {
            date: "2026-08-08".into(),
            git_rev: "abc123".into(),
            scenario: "rush".into(),
            fast: true,
            points: vec![
                SoakPoint {
                    factor: 1.0,
                    offered_per_s: 900.0,
                    goodput_per_s: 890.5,
                    p50_ms: 2.5,
                    p99_ms: 11.0,
                    shed_rate: 0.0,
                    degraded_rate: 0.0,
                },
                SoakPoint {
                    factor: 8.0,
                    offered_per_s: 7200.0,
                    goodput_per_s: 4100.0,
                    p50_ms: 9.0,
                    p99_ms: 48.0,
                    shed_rate: 0.31,
                    degraded_rate: 0.12,
                },
            ],
        };
        let back = SoakRecord::from_json(&rec.to_json()).unwrap();
        assert_eq!(back, rec);
        assert!(SoakRecord::from_json("{\"date\": \"x\"}").is_err());

        let dir = std::env::temp_dir().join(format!("soak_rec_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        rec.write_to(&dir).unwrap();
        let (path, read_back) = SoakRecord::latest_in(&dir, true).unwrap();
        assert!(path.ends_with("SOAK_2026-08-08.json"));
        assert_eq!(read_back, rec);
        assert!(SoakRecord::latest_in(&dir, false).is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn civil_date_math() {
        assert_eq!(civil_from_days(0), (1970, 1, 1));
        assert_eq!(civil_from_days(19_723), (2024, 1, 1));
        assert_eq!(civil_from_days(19_782), (2024, 2, 29)); // leap day
        assert_eq!(civil_from_days(-1), (1969, 12, 31));
    }

    #[test]
    fn write_and_latest_roundtrip() {
        let dir = std::env::temp_dir().join(format!("bench_rec_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut old = sample();
        old.date = "2026-08-01".into();
        old.write_to(&dir).unwrap();
        let new = sample();
        new.write_to(&dir).unwrap();
        // Latest matching the fast flag wins; a mismatched flag is skipped.
        let (path, rec) = BenchRecord::latest_in(&dir, true).unwrap();
        assert!(path.ends_with("BENCH_2026-08-08.json"));
        assert_eq!(rec, new);
        assert!(BenchRecord::latest_in(&dir, false).is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
