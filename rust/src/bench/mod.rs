//! Benchmark harness (criterion is not in the offline crate universe).
//!
//! `benches/*.rs` binaries use [`Harness`] for warmup → timed iterations →
//! robust statistics, and the [`stats`] module for the mean/stddev/
//! percentile summaries printed in the paper-style tables.

pub mod harness;
pub mod stats;

pub use harness::{BenchResult, Harness};
pub use stats::Summary;
