//! Benchmark harness (criterion is not in the offline crate universe).
//!
//! `benches/*.rs` binaries use [`Harness`] for warmup → timed iterations →
//! robust statistics, and the [`stats`] module for the mean/stddev/
//! percentile summaries printed in the paper-style tables. The [`record`]
//! module persists each serve-throughput run as a `BENCH_<date>.json`
//! snapshot and compares against the previous one (the perf trajectory);
//! soak runs persist their degradation curves as `SOAK_<date>.json` the
//! same way.

pub mod harness;
pub mod record;
pub mod stats;

pub use harness::{BenchResult, Harness};
pub use record::{BenchRecord, BenchRow, SoakPoint, SoakRecord};
pub use stats::Summary;
