//! Benchmark harness (criterion is not in the offline crate universe).
//!
//! `benches/*.rs` binaries use [`Harness`] for warmup → timed iterations →
//! robust statistics, and the [`stats`] module for the mean/stddev/
//! percentile summaries printed in the paper-style tables. The [`record`]
//! module persists each serve-throughput run as a `BENCH_<date>.json`
//! snapshot and compares against the previous one (the perf trajectory);
//! soak runs persist their degradation curves as `SOAK_<date>.json` the
//! same way. The [`compress_report`] module is the compression path's
//! counterpart: `rsic compress --report-out` writes per-layer spectral
//! and timing telemetry as `COMPRESS_REPORT_<date>.json`.

pub mod compress_report;
pub mod harness;
pub mod record;
pub mod stats;

pub use compress_report::{CompressReport, LayerReport};
pub use harness::{BenchResult, Harness};
pub use record::{BenchRecord, BenchRow, SoakPoint, SoakRecord};
pub use stats::Summary;
