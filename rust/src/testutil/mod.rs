//! Test infrastructure: a minimal property-testing runner (proptest is not
//! in the offline crate universe) and golden-data helpers.

pub mod golden;
pub mod prop;

pub use prop::{Gen, PropRunner};

/// Assert two f32 slices are elementwise close.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let d = (*x as f64 - *y as f64).abs();
        assert!(d <= atol, "{what}: element {i}: {x} vs {y} (|Δ|={d} > {atol})");
    }
}

/// Relative closeness for scalars with a floor to avoid 0/0.
pub fn assert_relclose(a: f64, b: f64, rtol: f64, what: &str) {
    let denom = a.abs().max(b.abs()).max(1e-12);
    let rel = (a - b).abs() / denom;
    assert!(rel <= rtol, "{what}: {a} vs {b} (rel {rel} > {rtol})");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allclose_passes_and_fails() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, "ok");
        let r = std::panic::catch_unwind(|| assert_allclose(&[1.0], &[2.0], 1e-5, "bad"));
        assert!(r.is_err());
    }

    #[test]
    fn relclose() {
        assert_relclose(100.0, 100.5, 0.01, "ok");
        let r = std::panic::catch_unwind(|| assert_relclose(1.0, 2.0, 0.01, "bad"));
        assert!(r.is_err());
    }
}
