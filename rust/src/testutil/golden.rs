//! Golden-data helpers: numpy-produced reference factorizations are
//! shipped in `artifacts/data/golden_linalg.tenz` by `make artifacts`;
//! tests that need them skip gracefully when artifacts are absent so
//! `cargo test` stays green before the Python build step.

use crate::io::tenz::TensorFile;
use std::path::PathBuf;

/// Path to a golden data file under the artifacts dir.
pub fn golden_path(name: &str) -> PathBuf {
    crate::artifacts_dir().join("data").join(name)
}

/// Load a golden `.tenz`, or `None` when artifacts have not been built.
/// Set `RSIC_REQUIRE_ARTIFACTS=1` to turn the skip into a hard failure
/// (CI after `make artifacts`).
pub fn load_golden(name: &str) -> Option<TensorFile> {
    let path = golden_path(name);
    match TensorFile::read(&path) {
        Ok(tf) => Some(tf),
        Err(_) => {
            if std::env::var("RSIC_REQUIRE_ARTIFACTS").map(|v| v == "1").unwrap_or(false) {
                panic!("golden data {path:?} missing but RSIC_REQUIRE_ARTIFACTS=1");
            }
            eprintln!("[skip] golden data {path:?} not present (run `make artifacts`)");
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_path_layout() {
        let p = golden_path("x.tenz");
        assert!(p.to_string_lossy().ends_with("data/x.tenz"));
    }

    #[test]
    fn missing_golden_is_none() {
        std::env::remove_var("RSIC_REQUIRE_ARTIFACTS");
        assert!(load_golden("definitely_not_here.tenz").is_none());
    }
}
