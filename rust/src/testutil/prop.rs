//! A small seeded property-testing runner (proptest stand-in).
//!
//! ```no_run
//! use rsi_compress::testutil::prop::{Gen, PropRunner};
//! PropRunner::new(64).run("rank bounded", |g| {
//!     let (c, d) = (g.usize_in(1, 20), g.usize_in(1, 20));
//!     let k = rsi_compress::util::rank_for_alpha(g.f64_in(0.01, 1.0), c, d);
//!     assert!(k >= 1 && k <= c.min(d));
//! });
//! ```
//!
//! On failure the runner reports the case index and seed so the exact
//! counterexample replays with `PropRunner::replay(seed)`.

use crate::rng::{GaussianSource, Pcg64};
use crate::tensor::Mat;

/// Random input generator handed to each property case.
pub struct Gen {
    rng: Pcg64,
    gauss: GaussianSource,
    seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Pcg64::new(seed), gauss: GaussianSource::new(seed ^ 0x9e3779b9), seed }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    /// Gaussian matrix with entries scaled by `sigma`.
    pub fn mat(&mut self, rows: usize, cols: usize, sigma: f32) -> Mat<f32> {
        crate::tensor::init::gaussian(rows, cols, sigma, &mut self.gauss)
    }

    /// A matrix with a random synthetic spectrum (random decay regime) —
    /// the workhorse input for RSI invariants.
    pub fn spectral_mat(&mut self, rows: usize, cols: usize) -> Mat<f32> {
        let head = self.f64_in(1.0, 50.0);
        let decay = self.f64_in(0.01, 0.5);
        let tail = self.f64_in(0.01, 2.0);
        let p = self.f64_in(0.1, 2.0);
        let shape = crate::tensor::init::SpectrumShape { head, decay, tail, p };
        let (r, c) = if rows <= cols { (rows, cols) } else { (cols, rows) };
        let m = crate::tensor::init::matrix_with_spectrum(r, c, &shape.values(r), &mut self.gauss);
        if rows <= cols {
            m
        } else {
            m.transpose()
        }
    }
}

/// Runs a property over many generated cases.
pub struct PropRunner {
    cases: usize,
    master_seed: u64,
}

impl PropRunner {
    pub fn new(cases: usize) -> Self {
        // Honor RSIC_PROP_CASES for heavier local runs.
        let cases = std::env::var("RSIC_PROP_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(cases);
        PropRunner { cases, master_seed: r_seed() }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.master_seed = seed;
        self
    }

    /// Run the property across all cases; panics with seed info on failure.
    pub fn run(&self, name: &str, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
        for case in 0..self.cases {
            let seed = crate::rng::derive_seed(self.master_seed, name, case as u64);
            let result = std::panic::catch_unwind(|| {
                let mut g = Gen::new(seed);
                prop(&mut g);
            });
            if let Err(payload) = result {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".into());
                panic!(
                    "property {name:?} failed at case {case}/{} (replay seed {seed:#x}):\n{msg}",
                    self.cases
                );
            }
        }
    }

    /// Replay a single failing seed.
    pub fn replay(seed: u64, prop: impl Fn(&mut Gen)) {
        let mut g = Gen::new(seed);
        prop(&mut g);
    }
}

// Default master seed: fixed for reproducible CI; override with
// RSIC_PROP_SEED for fuzzing sessions.
fn r_seed() -> u64 {
    std::env::var("RSIC_PROP_SEED").ok().and_then(|v| v.parse().ok()).unwrap_or(0x5151_c0de)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_ranges() {
        let mut g = Gen::new(1);
        for _ in 0..200 {
            let v = g.usize_in(3, 7);
            assert!((3..=7).contains(&v));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..=1.0).contains(&f));
        }
        let m = g.mat(4, 5, 1.0);
        assert_eq!(m.shape(), (4, 5));
    }

    #[test]
    fn runner_passes_trivial_property() {
        PropRunner::new(16).run("trivial", |g| {
            let a = g.usize_in(0, 100);
            assert!(a <= 100);
        });
    }

    #[test]
    fn runner_reports_failure_with_seed() {
        let r = std::panic::catch_unwind(|| {
            PropRunner::new(8).run("always-fails", |_g| panic!("boom"));
        });
        let err = r.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("replay seed"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn spectral_mat_orientations() {
        let mut g = Gen::new(5);
        let wide = g.spectral_mat(6, 15);
        assert_eq!(wide.shape(), (6, 15));
        let tall = g.spectral_mat(15, 6);
        assert_eq!(tall.shape(), (15, 6));
        assert!(wide.data().iter().all(|v| v.is_finite()));
    }
}
