//! Standard-normal sampling via Box–Muller, and Gaussian matrix fills for
//! the RSI sketch Ω ∈ R^{D×k} (paper Eq. 2.5).

use super::pcg::Pcg64;

/// A Gaussian N(0,1) source over PCG64, caching the spare Box–Muller draw.
#[derive(Debug, Clone)]
pub struct GaussianSource {
    rng: Pcg64,
    spare: Option<f64>,
}

impl GaussianSource {
    pub fn new(seed: u64) -> Self {
        GaussianSource { rng: Pcg64::new(seed), spare: None }
    }

    pub fn from_rng(rng: Pcg64) -> Self {
        GaussianSource { rng, spare: None }
    }

    /// One standard-normal draw.
    #[inline]
    pub fn next(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        // Box–Muller: u1 in (0,1) to keep log finite.
        let u1 = self.rng.next_f64_open();
        let u2 = self.rng.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fill a slice with N(0,1) f32 draws.
    pub fn fill_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.next() as f32;
        }
    }

    /// Fill a slice with N(0,1) f64 draws.
    pub fn fill_f64(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.next();
        }
    }

    /// A fresh row-major Gaussian buffer of `rows*cols` f32 values —
    /// the sketch matrix Ω.
    pub fn matrix_f32(&mut self, rows: usize, cols: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; rows * cols];
        self.fill_f32(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments() {
        let mut g = GaussianSource::new(17);
        let n = 400_000;
        let (mut s1, mut s2, mut s3, mut s4) = (0.0, 0.0, 0.0, 0.0);
        for _ in 0..n {
            let v = g.next();
            s1 += v;
            s2 += v * v;
            s3 += v * v * v;
            s4 += v * v * v * v;
        }
        let nf = n as f64;
        let mean = s1 / nf;
        let var = s2 / nf - mean * mean;
        let skew = s3 / nf;
        let kurt = s4 / nf;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
        assert!(skew.abs() < 0.03, "skew {skew}");
        assert!((kurt - 3.0).abs() < 0.1, "kurtosis {kurt}");
    }

    #[test]
    fn tail_mass() {
        // P(|Z| > 2) ≈ 4.55%.
        let mut g = GaussianSource::new(23);
        let n = 100_000;
        let tails = (0..n).filter(|_| g.next().abs() > 2.0).count();
        let frac = tails as f64 / n as f64;
        assert!((frac - 0.0455).abs() < 0.006, "tail {frac}");
    }

    #[test]
    fn deterministic_matrix() {
        let mut a = GaussianSource::new(5);
        let mut b = GaussianSource::new(5);
        assert_eq!(a.matrix_f32(8, 8), b.matrix_f32(8, 8));
    }

    #[test]
    fn fill_f32_finite() {
        let mut g = GaussianSource::new(1);
        let mut buf = vec![0.0f32; 4096];
        g.fill_f32(&mut buf);
        assert!(buf.iter().all(|v| v.is_finite()));
        // Not all equal.
        assert!(buf.windows(2).any(|w| w[0] != w[1]));
    }
}
