//! Deterministic random number generation.
//!
//! The crate universe ships no `rand`, so we implement the RNG substrate
//! ourselves: a PCG64 (XSL-RR 128/64) generator and a Box–Muller Gaussian
//! transform. RSI draws its random test matrix Ω from [`GaussianSource`].
//!
//! Determinism matters here: every experiment in the paper's evaluation is
//! repeated over independent sketches; we reproduce that with seed streams
//! derived from a master seed so every table row is replayable.

pub mod gaussian;
pub mod pcg;

pub use gaussian::GaussianSource;
pub use pcg::Pcg64;

/// Derive the seed for trial `t` of experiment `label` from a master seed.
///
/// Uses SplitMix64-style mixing over (seed, fnv(label), t) so distinct
/// labels/trials give decorrelated streams.
pub fn derive_seed(master: u64, label: &str, trial: u64) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in label.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    let mut z = master ^ h.rotate_left(17) ^ trial.wrapping_mul(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_seeds_distinct() {
        let a = derive_seed(42, "fig41", 0);
        let b = derive_seed(42, "fig41", 1);
        let c = derive_seed(42, "fig42", 0);
        let d = derive_seed(43, "fig41", 0);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d);
        // Deterministic.
        assert_eq!(a, derive_seed(42, "fig41", 0));
    }
}
