//! PCG64 (XSL-RR 128/64) — O'Neill's permuted congruential generator.
//!
//! 128-bit LCG state, 64-bit output via xor-shift-low + random rotation.
//! Passes BigCrush; more than adequate for sketching matrices.

/// PCG64 XSL-RR generator.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Seed with a 64-bit seed and the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Seed with an explicit stream id (must differ in low bits to give a
    /// different sequence; the increment is forced odd).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let s = self.state;
        let xored = ((s >> 64) as u64) ^ (s as u64);
        let rot = (s >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in the open interval (0, 1) — safe for log().
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64 + 0.5) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) by Lemire's multiply-shift with rejection.
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::with_stream(1, 10);
        let mut b = Pcg64::with_stream(1, 11);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut rng = Pcg64::new(3);
        for _ in 0..10_000 {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
            let w = rng.next_f64_open();
            assert!(w > 0.0 && w < 1.0);
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut rng = Pcg64::new(11);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sum2 = 0.0;
        for _ in 0..n {
            let v = rng.next_f64();
            sum += v;
            sum2 += v * v;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var {var}");
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut rng = Pcg64::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
