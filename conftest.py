import os
import sys

# Allow `pytest python/tests/` from the repo root: the build-time package
# lives under python/.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
