//! Single-layer analysis (paper §4.1): normalized error + runtime versus
//! rank k and iteration count q on the scaled VGG19 fc1 layer — the
//! machinery behind Figs 4.1/4.2, runnable as a standalone example.
//!
//! Run: `make artifacts && cargo run --release --example single_layer_sweep`

use rsi_compress::cli::experiments::{load_layer, single_layer_sweep};
use rsi_compress::compress::backend::BackendKind;
use rsi_compress::model::ModelKind;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("RSIC_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let layer = load_layer(ModelKind::SynthVgg, "layers.0")?;
    println!("analyzing {}", layer.label);
    let ranks: &[usize] = if fast { &[64, 256] } else { &[64, 128, 256, 512, 832] };
    let trials = if fast { 2 } else { 5 };
    let sweep = single_layer_sweep(&layer, ranks, &[1, 2, 3, 4], trials, BackendKind::Native, 42)?;
    println!("{}", sweep.error_fig.render());
    println!("{}", sweep.runtime_fig.render());
    println!("exact SVD baseline: {:.3}s — compare the speedup column shape to Fig 4.1(b)", sweep.svd_seconds);
    Ok(())
}
