//! Quickstart: compress one weight matrix with RSVD vs RSI and inspect
//! the quality difference the paper is about (no artifacts needed —
//! native backend on a synthetic pretrained-like layer).
//!
//! Run: `cargo run --release --example quickstart`

use rsi_compress::compress::{rsi_factorize, NativeEngine, RsiOptions};
use rsi_compress::linalg::svd::svd_via_gram;
use rsi_compress::rng::GaussianSource;
use rsi_compress::tensor::init::{matrix_with_spectrum, SpectrumShape};

fn main() {
    // A 256×1024 layer with the paper's Fig-1.1 spectrum: fast head decay,
    // slow tail — the regime where plain RSVD struggles.
    let mut g = GaussianSource::new(7);
    let spectrum = SpectrumShape::pretrained_like().values(256);
    let w = matrix_with_spectrum(256, 1024, &spectrum, &mut g);
    let k = 32;

    println!("layer: {}x{}, target rank k={k}", w.rows(), w.cols());
    let svd = svd_via_gram(&w);
    let optimal = svd.s[k];
    println!("optimal rank-{k} error (s_k+1): {optimal:.4}\n");

    println!("{:<10} {:>14} {:>18} {:>12}", "method", "‖W−AB‖₂", "normalized error", "params");
    for q in [1usize, 2, 3, 4] {
        let f = rsi_factorize(&w, k, &RsiOptions::with_q(q, 42), &NativeEngine);
        let err = f.spectral_error(&w);
        let name = if q == 1 { "rsvd".to_string() } else { format!("rsi(q={q})") };
        println!(
            "{:<10} {:>14.4} {:>18.3} {:>12}",
            name,
            err,
            err / optimal,
            f.param_count()
        );
    }
    println!(
        "\ndense params: {} → rank-{k} factors store {:.1}% of that",
        w.rows() * w.cols(),
        100.0 * (w.rows() + w.cols()) as f64 * k as f64 / (w.rows() * w.cols()) as f64
    );
    println!("(compare: normalized error → 1.0 means optimal; the paper's Fig 4.1)");
}
