//! End-to-end driver (the repo's headline validation): load the
//! "pretrained" synthvgg + synthvit checkpoints built by `make artifacts`,
//! compress every linear layer through the full coordinator pipeline at a
//! grid of (α, q), evaluate each compressed model on its held-out 10-class
//! eval set through the compiled forward artifacts, and print Table-4.1
//! style rows. Also validates Theorem 3.2 on the head layer.
//!
//! Run: `make artifacts && cargo run --release --example compress_model`

use rsi_compress::cli::experiments;
use rsi_compress::compress::backend::BackendKind;
use rsi_compress::compress::rsi::RsiOptions;
use rsi_compress::model::ModelKind;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("RSIC_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let alphas: &[f64] = if fast { &[0.4] } else { &[0.8, 0.4, 0.2] };
    let qs: &[usize] = if fast { &[1, 4] } else { &[1, 2, 4] };

    for model in [ModelKind::SynthVgg, ModelKind::SynthVit] {
        println!("=== {} ===", model.name());
        let opts = RsiOptions { seed: 42, ..Default::default() };
        let out = experiments::table_41(model, alphas, qs, BackendKind::Native, opts, None)?;
        println!("{}", out.table.render());
        println!("{}", out.runtime.render());
    }

    println!("=== Theorem 3.2 (softmax perturbation bound, synthvgg head) ===");
    for (alpha, q) in [(0.4, 1usize), (0.2, 1), (0.2, 4)] {
        let rep = experiments::theorem_check(alpha, q, 42)?;
        println!(
            "alpha={alpha:<4} q={q}: measured max ‖Δp‖∞ = {:.5} ≤ bound {:.5} (tightness {:.3}) {}",
            rep.max_deviation,
            rep.bound,
            rep.tightness,
            if rep.holds() { "✓" } else { "VIOLATED" }
        );
        assert!(rep.holds(), "Theorem 3.2 must hold");
    }
    println!("\nall layers composed: checkpoint → pipeline → PJRT forward → top-k ✓");
    Ok(())
}
