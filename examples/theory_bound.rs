//! Theorem 3.2 validation across the (α, q) grid: for every configuration
//! the measured softmax perturbation must sit under ½·R·‖W−W̃‖₂, and the
//! tightness ratio shows how conservative the bound is in practice
//! (Remark 3.3).
//!
//! Run: `make artifacts && cargo run --release --example theory_bound`

use rsi_compress::cli::experiments::theorem_check;

fn main() -> anyhow::Result<()> {
    println!(
        "{:<8} {:<4} {:>12} {:>14} {:>12} {:>10}",
        "alpha", "q", "bound", "max ‖Δp‖∞", "tightness", "holds"
    );
    let mut worst_tightness = 0.0f64;
    for alpha in [0.8, 0.4, 0.2] {
        for q in [1usize, 2, 4] {
            let rep = theorem_check(alpha, q, 42)?;
            worst_tightness = worst_tightness.max(rep.tightness);
            println!(
                "{:<8} {:<4} {:>12.5} {:>14.6} {:>12.4} {:>10}",
                alpha,
                q,
                rep.bound,
                rep.max_deviation,
                rep.tightness,
                if rep.holds() { "✓" } else { "VIOLATED" }
            );
            assert!(rep.holds(), "bound violated at alpha={alpha}, q={q}");
        }
    }
    println!("\nTheorem 3.2 held for all 9 configurations (max tightness {worst_tightness:.4}).");
    println!("Tightness < 1 everywhere: the spectral envelope is conservative, as Remark 3.3 notes.");
    Ok(())
}
